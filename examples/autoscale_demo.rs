//! Auto-scaling with the plan/execute API — the §4/§5 control loop in
//! action.
//!
//! Part 1 walks the plan lifecycle by hand: a pure Algorithm 1 planner
//! round proposes a `ScalePlan`, `dry_run` prices it without touching any
//! ledger, `PlanExecutor::execute` commits it — and the per-op dry-run
//! cost equals the executed cost *exactly* (the Table 2 parity contract).
//!
//! Part 2 runs the closed loop in the simulator: traffic ramps 2 → 45 RPS
//! over 60 s; the controller emits plans that execute **in flight** while
//! requests are served (replication overlaps serving; only the §6.5
//! comm-setup barrier pauses the instance).
//!
//! ```bash
//! cargo run --release --example autoscale_demo
//! ```

use cocoserve::autoscale::{scale_up, ScaleUpConfig};
use cocoserve::baselines;
use cocoserve::cluster::Cluster;
use cocoserve::model::cost::MIB;
use cocoserve::ops::{ModuleOps, PlanExecutor};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, Simulation};
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn main() {
    let cfg = SimConfig::paper_13b();

    // ---- part 1: plan → dry-run → execute, with cost parity -------------
    println!("== plan lifecycle: plan → validate → dry-run → execute ==\n");
    let cost_model = cfg.cost_model();
    let ops = ModuleOps::new(&cost_model, cfg.dtype_bytes, "inst0");
    let mut cluster = Cluster::paper_testbed();
    let mut placement = Placement::single_device(cfg.model.n_layers, 0);
    ops.deploy_instance(&mut cluster, &placement).unwrap();

    let up_cfg = ScaleUpConfig { max_ops_per_round: 6, ..Default::default() };
    let proposal = scale_up(&ops, &cluster, &placement, &up_cfg);
    println!(
        "Algorithm 1 planned {} replication(s): S_homo {:.3} -> {:.3}",
        proposal.plan.len(),
        proposal.speedup_before,
        proposal.speedup_after
    );

    proposal.plan.validate(&ops, &cluster, &placement).unwrap();
    let dry = proposal.plan.dry_run(&ops, &cluster, &placement).unwrap();
    let executed = PlanExecutor::new(&ops)
        .execute(&mut cluster, &mut placement, &proposal.plan)
        .unwrap();

    println!("\n  op                      dry-run        executed       match");
    for (i, op) in proposal.plan.ops.iter().enumerate() {
        let (d, e) = (dry.per_op[i], executed.per_op[i]);
        println!(
            "  {:<22} {:>9.4}s {:>6.0}MB {:>7.4}s {:>6.0}MB   {}",
            op.describe(),
            d.time_s,
            d.dst_bytes / MIB,
            e.time_s,
            e.dst_bytes / MIB,
            if d == e { "exact" } else { "MISMATCH" },
        );
    }
    assert_eq!(dry, executed, "Table 2 parity: dry-run must equal executed");
    println!(
        "\n  total: dry-run {:.4}s == executed {:.4}s (bit-identical) — the\n\
         \x20 controller can price a reconfiguration before committing to it.\n",
        dry.total.time_s, executed.total.time_s
    );

    // ---- part 2: the closed loop, scaling in flight ----------------------
    println!("== auto-scaling demo: traffic ramp 2 → 45 RPS over 60 s ==\n");
    let trace = Trace::generate(
        Arrival::Ramp { from: 2.0, to: 45.0 },
        LengthDist::alpaca(),
        60.0,
        23,
    );
    println!("{} requests generated\n", trace.len());

    for (label, policy) in [
        ("static (no autoscale)", baselines::cocoserve_no_autoscale(16)),
        ("CoCoServe autoscaled ", baselines::cocoserve(16)),
    ] {
        let sim = Simulation::new(
            cfg.clone(),
            Cluster::paper_testbed(),
            vec![(Placement::single_device(cfg.model.n_layers, 0), policy)],
        );
        let r = sim.run(&trace, 60.0);
        let mut lat = r.merged_latency();
        let p = &r.placements[0];
        let degrees: Vec<usize> = (0..p.n_layers).map(|l| p.degree(l)).collect();
        let replicas: usize = degrees.iter().map(|d| d - 1).sum();
        println!(
            "{label}: lat mean {:.2}s p95 {:.2}s · thr {:.0} tok/s · SLO {:.1}%",
            lat.mean(),
            lat.p95(),
            r.total_throughput_tps(),
            r.slo_attainment() * 100.0
        );
        println!(
            "  scaling: {} up / {} down · {} op events ({} aborted plans) · \
             final replica count {replicas} · max degree {}",
            r.scale_ups,
            r.scale_downs,
            r.op_events.len(),
            r.plans_aborted,
            degrees.iter().max().unwrap()
        );
        if let (Some(first), Some(last)) = (r.op_events.first(), r.op_events.last()) {
            let served_during = r.monitors[0]
                .completions()
                .iter()
                .filter(|c| c.finish_s >= first.t && c.finish_s <= last.t)
                .count();
            println!(
                "  in-flight: ops span t={:.1}s..{:.1}s with {served_during} requests \
                 completing inside the window (no global pause)",
                first.t, last.t
            );
        }
    }
    println!(
        "\nThe autoscaled run converts idle devices into layer replicas as the\n\
         ramp builds — replication count rises with load, exactly the §3.2\n\
         observation driving Algorithm 1 — and every operation executes as a\n\
         timed OpStarted/OpCompleted event pair while serving continues."
    );
}
