//! Streaming statistics and percentile summaries (std-only).
//!
//! Used by the monitor (latency/SLO accounting), the simulator, and the
//! bench harness. `Summary` keeps raw samples (bounded experiments), which
//! makes exact percentiles trivial; `Welford` is the O(1)-memory fallback
//! for long-running serving loops.

/// Exact-sample summary: mean / min / max / percentiles over kept samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    /// Record a batch of samples.
    pub fn extend(&mut self, xs: &[f64]) {
        self.samples.extend_from_slice(xs);
        self.sorted = false;
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Smallest sample (+inf when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (−inf when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (0.0 for fewer than two samples).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    /// Sort the samples once (no-op when already sorted) and return the
    /// sorted view. Call after the last `add` to make any number of
    /// subsequent percentile reads O(1): interleaving pushes with
    /// percentile reads would otherwise trigger a full re-sort per read.
    pub fn finalize(&mut self) -> &[f64] {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
        &self.samples
    }

    /// Exact percentile by nearest-rank (q in [0, 100]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.finalize();
        let rank = ((q / 100.0) * (self.samples.len() - 1) as f64).round();
        self.samples[rank as usize]
    }

    /// Batch percentile read: one sort for all requested quantiles —
    /// the bench-report path (`[p50, p95, p99]` in a single pass).
    pub fn percentiles(&mut self, qs: &[f64]) -> Vec<f64> {
        qs.iter().map(|&q| self.percentile(q)).collect()
    }

    /// Median.
    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// 95th percentile.
    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    /// 99th percentile.
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Welford online mean/variance — O(1) memory for unbounded streams.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Fold one observation into the running moments.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (0.0 for fewer than two observations).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Streaming quantile estimator — the P² algorithm (Jain & Chlamtac,
/// CACM 1985). O(1) memory (five markers) for unbounded streams: the
/// fleet-scale bench tracks p50/p99 over 500k+ latencies without
/// materializing (or sorting) a merged sample vector, where an exact
/// [`Summary`] would hold — and re-sort — a second linear copy.
///
/// Exact while fewer than five observations have arrived; afterwards the
/// markers track the target quantile with parabolic interpolation.
/// Deterministic: pure f64 arithmetic over the observation sequence.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    /// Target quantile in (0, 1), e.g. 0.99.
    q: f64,
    /// Marker heights (estimates of the 0, q/2, q, (1+q)/2, 1 quantiles).
    h: [f64; 5],
    /// Actual marker positions (1-based observation ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: usize,
}

impl P2Quantile {
    /// Estimator for quantile `q` in (0, 1), e.g. `0.99` for p99.
    pub fn new(q: f64) -> P2Quantile {
        assert!(q > 0.0 && q < 1.0, "quantile must be in (0, 1)");
        P2Quantile {
            q,
            h: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            dn: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            count: 0,
        }
    }

    /// Observation count.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Fold one observation into the marker state.
    pub fn add(&mut self, x: f64) {
        if self.count < 5 {
            self.h[self.count] = x;
            self.count += 1;
            if self.count == 5 {
                self.h.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            }
            return;
        }
        // locate the cell containing x, clamping the extremes
        let k = if x < self.h[0] {
            self.h[0] = x;
            0
        } else if x >= self.h[4] {
            self.h[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 1..4 {
                if x >= self.h[i] {
                    k = i;
                }
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // adjust interior markers toward their desired positions
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let hp = self.parabolic(i, d);
                self.h[i] = if self.h[i - 1] < hp && hp < self.h[i + 1] {
                    hp
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
        self.count += 1;
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (n, h) = (&self.n, &self.h);
        h[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.h[i] + d * (self.h[j] - self.h[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate of the target quantile (nearest-rank exact for
    /// fewer than five samples; 0.0 when empty).
    pub fn value(&self) -> f64 {
        match self.count {
            0 => 0.0,
            c if c < 5 => {
                let mut v = self.h[..c].to_vec();
                v.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
                let rank = (self.q * (c - 1) as f64).round() as usize;
                v[rank]
            }
            _ => self.h[2],
        }
    }
}

/// Fixed-bucket histogram over [lo, hi) with overflow/underflow buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    under: u64,
    over: u64,
    count: u64,
}

impl Histogram {
    /// `n_buckets` equal-width buckets spanning `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Histogram { lo, hi, buckets: vec![0; n_buckets], under: 0, over: 0, count: 0 }
    }

    /// Count one sample. `x == hi` lands in the overflow bucket (the
    /// range is half-open); a finite `x` just under `hi` whose scaled
    /// index rounds up to `n` is clamped into the last bucket (float
    /// rounding must never index out of bounds). NaN is a hard error —
    /// `NaN as usize` is 0, which would silently corrupt bucket 0.
    pub fn add(&mut self, x: f64) {
        assert!(!x.is_nan(), "NaN histogram sample");
        self.count += 1;
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.buckets.len();
            let i = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[i.min(n - 1)] += 1;
        }
    }

    /// Total samples, including under/overflow.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fraction of samples at or above `x` (bucket-resolution approximation).
    pub fn frac_ge(&self, x: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let mut n = self.over;
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if self.lo + (i as f64 + 0.5) * width >= x {
                n += c;
            }
        }
        if x <= self.lo {
            n += self.under;
        }
        n as f64 / self.count as f64
    }

    /// In-range bucket counts (excludes under/overflow).
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }
}

/// Linear-regression slope — used by trend detection in the controller and
/// by bench analysis (throughput-vs-rps curves).
pub fn slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if var == 0.0 {
        0.0
    } else {
        cov / var
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.p50(), 3.0); // nearest-rank of 50% over 4 samples
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_monotone() {
        let mut s = Summary::new();
        for i in 0..1000 {
            s.add(i as f64);
        }
        assert!(s.percentile(10.0) <= s.percentile(50.0));
        assert!(s.percentile(50.0) <= s.percentile(99.0));
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 999.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0.0);
    }

    #[test]
    fn welford_matches_summary() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::default();
        let mut s = Summary::new();
        for &x in &xs {
            w.add(x);
            s.add(x);
        }
        assert!((w.mean() - s.mean()).abs() < 1e-12);
        assert!((w.std() - s.std()).abs() < 1e-12);
    }

    #[test]
    fn finalize_sorts_once_and_reads_are_stable() {
        let mut s = Summary::new();
        for i in (0..100).rev() {
            s.add(i as f64);
        }
        let sorted = s.finalize().to_vec();
        assert_eq!(sorted[0], 0.0);
        assert_eq!(sorted[99], 99.0);
        // batch path: one sort for all three reads
        let ps = s.percentiles(&[50.0, 95.0, 99.0]);
        assert_eq!(ps, vec![50.0, 94.0, 98.0]);
        // interleaved add invalidates; reads stay correct
        s.add(1000.0);
        assert_eq!(s.percentile(100.0), 1000.0);
    }

    #[test]
    fn p2_exact_below_five_samples() {
        let mut p = P2Quantile::new(0.5);
        assert_eq!(p.value(), 0.0);
        for x in [5.0, 1.0, 3.0] {
            p.add(x);
        }
        assert_eq!(p.value(), 3.0);
        assert_eq!(p.count(), 3);
    }

    #[test]
    fn p2_tracks_exact_quantiles_on_uniform_stream() {
        // deterministic LCG stream; the estimate must land within a few
        // percent of the exact sample quantile at n = 50k.
        for &q in &[0.5, 0.9, 0.99] {
            let mut p = P2Quantile::new(q);
            let mut s = Summary::new();
            let mut x: u64 = 12345;
            for _ in 0..50_000 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let v = (x >> 11) as f64 / (1u64 << 53) as f64; // U[0,1)
                p.add(v);
                s.add(v);
            }
            let exact = s.percentile(q * 100.0);
            assert!(
                (p.value() - exact).abs() < 0.02,
                "q={q}: p2 {} vs exact {exact}",
                p.value()
            );
        }
    }

    #[test]
    fn p2_is_deterministic_and_bounded() {
        let run = || {
            let mut p = P2Quantile::new(0.99);
            for i in 0..10_000 {
                p.add(((i * 7919) % 1000) as f64);
            }
            p.value()
        };
        assert_eq!(run().to_bits(), run().to_bits());
        let v = run();
        assert!((0.0..=999.0).contains(&v), "{v}");
        assert!(v > 900.0, "p99 of 0..999 uniform-ish: {v}");
    }

    #[test]
    fn histogram_frac_ge() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert!((h.frac_ge(5.0) - 0.5).abs() < 1e-9);
        assert_eq!(h.frac_ge(100.0), 0.0);
        assert_eq!(h.frac_ge(0.0), 1.0);
    }

    #[test]
    fn histogram_overflow_buckets() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(-1.0);
        h.add(2.0);
        assert_eq!(h.count(), 2);
        assert!((h.frac_ge(0.5) - 0.5).abs() < 1e-9); // only the overflow
    }

    #[test]
    fn histogram_bucket_index_edge_cases() {
        // regression: the scaled bucket index must be clamped — a sample
        // at (or float-rounding onto) the upper edge used to be able to
        // index one past the last bucket
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(10.0); // x == hi: overflow bucket, not buckets[10]
        assert_eq!(h.buckets().iter().sum::<u64>(), 0);
        assert_eq!(h.count(), 1);

        // largest representable value below hi: clamp puts it in the
        // last bucket even when (x-lo)/(hi-lo)*n rounds up to n
        let just_below = f64::from_bits(10.0_f64.to_bits() - 1);
        assert!(just_below < 10.0);
        h.add(just_below);
        assert_eq!(*h.buckets().last().unwrap(), 1);

        // lower edge is inclusive: bucket 0, not underflow
        h.add(0.0);
        assert_eq!(h.buckets()[0], 1);

        // a single-bucket histogram exercises the clamp hardest
        let mut one = Histogram::new(0.0, 1.0, 1);
        one.add(0.999999999999);
        one.add(0.0);
        assert_eq!(one.buckets(), &[2]);
    }

    #[test]
    #[should_panic(expected = "NaN histogram sample")]
    fn histogram_rejects_nan_samples() {
        // regression: `NaN as usize` is 0 — a NaN sample used to be
        // silently counted into bucket 0
        Histogram::new(0.0, 1.0, 4).add(f64::NAN);
    }

    #[test]
    fn slope_of_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        assert!((slope(&xs, &ys) - 2.0).abs() < 1e-12);
        assert_eq!(slope(&[1.0], &[2.0]), 0.0);
    }
}
