//! Event-kernel contracts, tested through the public simulation API.
//!
//! * **Golden deterministic replay** — the same seed + trace must produce
//!   byte-identical metrics JSON across runs, for every scenario shape and
//!   every baseline policy. This is what makes every bench number in
//!   EXPERIMENTS-style reports regenerable.
//! * **KV capacity invariant** — across random traffic and random
//!   scale-up/scale-down activity, the KV bytes the per-instance state
//!   machines mirror into the device ledgers never push any device past
//!   its capacity (the ledger's peak high-water mark stays ≤ `mem_bytes`),
//!   and the per-instance KV accounting stays self-consistent.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::util::{prop, rng::Rng};
use cocoserve::workload::Trace;

fn run_fleet(
    n_instances: usize,
    n_devices: usize,
    policy: SimPolicy,
    trace: &Trace,
    duration_s: f64,
) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(n_devices, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..n_instances)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % n_devices),
                policy,
            )
        })
        .collect();
    let sim = Simulation::new(cfg, cluster, placements);
    sim.run(trace, duration_s)
}

#[test]
fn golden_replay_is_byte_identical_across_scenarios() {
    // Two independent end-to-end runs per scenario; the metrics JSON must
    // match byte for byte (same seed ⇒ same event sequence ⇒ same report).
    for (name, trace) in Trace::scenario_sweep(20.0, 15.0, 77) {
        let a = run_fleet(2, 2, baselines::cocoserve(32), &trace, 15.0);
        let b = run_fleet(2, 2, baselines::cocoserve(32), &trace, 15.0);
        let ja = a.to_json().to_string();
        let jb = b.to_json().to_string();
        assert_eq!(ja, jb, "scenario `{name}` not replay-deterministic");
        assert!(a.total_completed() > 0, "scenario `{name}` served nothing");
    }
}

#[test]
fn golden_replay_holds_for_every_policy() {
    let trace = Trace::burst(25.0, 15.0, 5);
    for (name, policy) in [
        ("hft", baselines::hft(16)),
        ("vllm", baselines::vllm_like(32)),
        ("coco", baselines::cocoserve(32)),
    ] {
        let a = run_fleet(1, 1, policy, &trace, 15.0).to_json().to_string();
        let b = run_fleet(1, 1, policy, &trace, 15.0).to_json().to_string();
        assert_eq!(a, b, "policy `{name}` not replay-deterministic");
    }
}

#[test]
fn metrics_json_is_parseable_and_complete() {
    let trace = Trace::steady(15.0, 10.0, 3);
    let r = run_fleet(2, 2, baselines::vllm_like(16), &trace, 10.0);
    let j = cocoserve::util::json::Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.req("completed").as_usize(), Some(r.total_completed()));
    assert_eq!(j.req("instances").as_arr().unwrap().len(), 2);
    assert_eq!(j.req("devices").as_arr().unwrap().len(), 2);
    for key in ["throughput_tps", "slo_attainment", "peak_mem_bytes", "duration_s"] {
        assert!(j.req(key).as_f64().is_some(), "missing {key}");
    }
}

#[test]
fn prop_kv_accounting_never_exceeds_device_capacity() {
    // Random fleet shape, random traffic shape, co-tenant pressure that
    // forces scale-down/OOM activity: after every run, no device ledger
    // may ever have held more than its capacity, and the per-instance KV
    // peaks must be consistent (live ≤ reserved, reserved ≥ 0).
    prop::check(
        "kv-capacity",
        |r: &mut Rng| {
            let seed = r.next_u64();
            let scenario = r.below(5) as usize;
            let rps = 10.0 + r.f64() * 30.0;
            let pressure_gib = r.f64() * 12.0;
            let policy = r.below(3) as usize;
            (seed, scenario, rps, pressure_gib, policy)
        },
        |&(seed, scenario, rps, pressure_gib, policy)| {
            let dur = 8.0;
            let trace = match scenario {
                0 => Trace::steady(rps, dur, seed),
                1 => Trace::diurnal(rps, dur, seed),
                2 => Trace::burst(rps, dur, seed),
                3 => Trace::ramp(rps, dur, seed),
                _ => Trace::two_tenant(rps, dur, seed),
            };
            let policy = match policy {
                0 => baselines::hft(16),
                1 => baselines::vllm_like(24),
                _ => baselines::cocoserve(24),
            };
            let cfg = SimConfig::paper_13b();
            let mut cluster = Cluster::paper_testbed();
            cluster
                .device_mut(0)
                .alloc("co-tenant", pressure_gib * GIB)
                .map_err(|e| e.to_string())?;
            let placement = Placement::single_device(cfg.model.n_layers, 0);
            let sim = Simulation::new(cfg, cluster, vec![(placement, policy)]);
            let r = sim.run(&trace, dur);
            for (d, &peak) in r.device_peak_bytes.iter().enumerate() {
                let cap = DeviceSpec::a100_40gb().mem_bytes;
                if peak > cap + 1.0 {
                    return Err(format!(
                        "device {d} peaked at {peak} bytes > capacity {cap}"
                    ));
                }
            }
            for (i, kv) in r.kv_stats.iter().enumerate() {
                if kv.reserved_bytes < 0.0 {
                    return Err(format!("instance {i} negative reservation"));
                }
                if kv.live_bytes > kv.reserved_bytes + 1.0 {
                    return Err(format!(
                        "instance {i} live {} > reserved {}",
                        kv.live_bytes, kv.reserved_bytes
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn golden_replay_inflight_plan_overlaps_serving() {
    // The §3.1 non-disruption claim, as a replayable kernel contract: a
    // CoCoServe instance under sustained load executes scaling plans *in
    // flight* — OpStarted/OpCompleted events interleave with request
    // completions (no global pause) — and the whole interleaving is
    // deterministic (byte-identical metrics JSON, op events included).
    let trace = Trace::steady(20.0, 30.0, 42);
    let a = run_fleet(1, 4, baselines::cocoserve(16), &trace, 30.0);
    let b = run_fleet(1, 4, baselines::cocoserve(16), &trace, 30.0);
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "in-flight scaling must be replay-deterministic"
    );

    assert!(a.scale_ups > 0, "no plans were admitted");
    assert!(!a.op_events.is_empty(), "no op events were logged");
    let started = a
        .op_events
        .iter()
        .filter(|e| e.phase == cocoserve::sim::OpPhase::Started)
        .count();
    let completed = a
        .op_events
        .iter()
        .filter(|e| e.phase == cocoserve::sim::OpPhase::Completed)
        .count();
    assert!(started > 0 && completed > 0, "{started} started / {completed} completed");

    // ops take simulated time: every completion strictly after its start
    let first_start = a
        .op_events
        .iter()
        .find(|e| e.phase == cocoserve::sim::OpPhase::Started)
        .map(|e| e.t)
        .unwrap();
    let last_end = a
        .op_events
        .iter()
        .rev()
        .find(|e| e.phase == cocoserve::sim::OpPhase::Completed)
        .map(|e| e.t)
        .unwrap();
    assert!(last_end > first_start, "ops must span simulated time");

    // the overlap itself: requests are in flight across the entire op
    // window (serving continued through scaling — no global pause), and
    // op events fire strictly inside the serving window (interleaving)
    let in_flight_across = a
        .monitors
        .iter()
        .flat_map(|m| m.completions())
        .filter(|c| c.arrival_s < first_start && c.finish_s > last_end)
        .count();
    assert!(
        in_flight_across > 0,
        "no request spanned the op window [{first_start}, {last_end}] — \
         scaling paused serving"
    );
    let (serving_from, serving_to) = a
        .monitors
        .iter()
        .flat_map(|m| m.completions())
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), c| {
            (lo.min(c.arrival_s), hi.max(c.finish_s))
        });
    let ops_during_serving = a
        .op_events
        .iter()
        .filter(|e| e.t >= serving_from && e.t <= serving_to)
        .count();
    assert!(ops_during_serving > 0, "ops did not interleave with serving");
}

#[test]
fn drain_completes_all_requests_under_light_load() {
    let trace = Trace::two_tenant(8.0, 12.0, 21);
    let n = trace.len();
    let r = run_fleet(2, 2, baselines::vllm_like(32), &trace, 12.0);
    assert_eq!(r.total_completed(), n, "all {n} requests must drain");
}

#[test]
fn different_seeds_diverge() {
    // Sanity check that the determinism tests are not vacuous: a different
    // trace seed must change the metrics.
    let a = run_fleet(1, 1, baselines::vllm_like(16), &Trace::steady(15.0, 10.0, 1), 10.0);
    let b = run_fleet(1, 1, baselines::vllm_like(16), &Trace::steady(15.0, 10.0, 2), 10.0);
    assert_ne!(a.to_json().to_string(), b.to_json().to_string());
}
