//! Metrics Monitor (§5): utilization + performance telemetry feeding the
//! controller.
//!
//! The paper's monitor reads NVML for utilization and the backend engine
//! (or injected timers) for performance. Here the same signals come from
//! the cluster ledgers (memory), busy-time accounting (compute) and the
//! engine/simulator completion stream (latency, tokens/s, SLO, OOM) — the
//! closed loop of Fig. 7.

use crate::cluster::Cluster;
use crate::util::json::{self, Json};
use crate::util::stats::Summary;

use crate::autoscale::ControllerInputs;

/// The fleet-level half of the telemetry spine: the aggregate load
/// window the fleet-scale controllers (reactive
/// [`crate::coordinator::FleetController`], predictive
/// [`crate::forecast::PredictiveController`]) read each control tick.
/// Assembled once per tick by the simulation kernel — streaming adds, no
/// allocation — so every fleet-level consumer sees the same numbers,
/// just as [`Monitor::controller_view`] is the single source of the
/// per-instance [`ControllerInputs`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetInputs {
    /// Instances not yet retired (the spin-up/drain bounds).
    pub live: usize,
    /// Instances currently accepting routed traffic.
    pub accepting: usize,
    /// Outstanding requests (pending + running + routed-but-undelivered)
    /// summed over live instances.
    pub outstanding: usize,
    /// Requests parked at the router under admission backpressure.
    pub parked: usize,
    /// Latency-sensitive share of `outstanding`. Filled by the kernel
    /// only under a class-aware routing policy; stays 0 in classless
    /// runs, so every classless pressure computation is unchanged.
    pub premium_outstanding: usize,
    /// Latency-sensitive share of `parked` (same classless-zero rule).
    pub premium_parked: usize,
}

impl FleetInputs {
    /// Fold one instance's state into the window.
    pub fn add_instance(&mut self, live: bool, accepting: bool, outstanding: usize) {
        if live {
            self.live += 1;
            self.outstanding += outstanding;
        }
        if accepting {
            self.accepting += 1;
        }
    }

    /// The fleet pressure signal: outstanding work (router-parked
    /// included) per traffic-accepting instance.
    pub fn mean_outstanding(&self) -> f64 {
        (self.outstanding + self.parked) as f64 / self.accepting.max(1) as f64
    }

    /// The premium pressure signal: latency-sensitive outstanding work
    /// (parked included) per traffic-accepting instance. Always 0.0 in
    /// classless runs — the premium fields are only filled under a
    /// class-aware routing policy.
    pub fn premium_mean_outstanding(&self) -> f64 {
        (self.premium_outstanding + self.premium_parked) as f64 / self.accepting.max(1) as f64
    }
}

/// One completed request's measurements.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// Id of the completed request.
    pub request_id: u64,
    /// Original arrival time (spans re-routes).
    pub arrival_s: f64,
    /// Completion time, including any carried OOM penalty.
    pub finish_s: f64,
    /// Prompt length served.
    pub prompt_tokens: usize,
    /// Tokens generated.
    pub output_tokens: usize,
    /// SLO class the request was admitted with (per-class attainment).
    pub class: crate::workload::SloClass,
}

impl Completion {
    /// End-to-end latency (arrival → finish, seconds).
    pub fn e2e_latency(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Rolling serving metrics over an experiment (or control window).
#[derive(Debug, Clone)]
pub struct Monitor {
    /// SLO: max acceptable end-to-end latency (seconds).
    pub slo_latency_s: f64,
    completions: Vec<Completion>,
    window_start: usize,
    oom_since_tick: u64,
    total_oom: u64,
    oom_affected: u64,
}

impl Monitor {
    /// A monitor judging completions against `slo_latency_s`.
    pub fn new(slo_latency_s: f64) -> Monitor {
        Monitor {
            slo_latency_s,
            completions: vec![],
            window_start: 0,
            oom_since_tick: 0,
            total_oom: 0,
            oom_affected: 0,
        }
    }

    /// Record one completed request.
    pub fn record(&mut self, c: Completion) {
        self.completions.push(c);
    }

    /// Record one OOM event (feeds the next controller window too).
    pub fn record_oom(&mut self) {
        self.oom_since_tick += 1;
        self.total_oom += 1;
    }

    /// Every completion recorded so far.
    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    /// Total OOM events recorded over the run.
    pub fn total_oom(&self) -> u64 {
        self.total_oom
    }

    /// Requests caught in an OOM failure (Fig. 11a's numerator).
    pub fn record_oom_affected(&mut self, n: u64) {
        self.oom_affected += n;
    }

    /// Requests caught in an OOM failure so far.
    pub fn oom_affected(&self) -> u64 {
        self.oom_affected
    }

    // ---- whole-experiment summaries (benches, EXPERIMENTS.md) -------------

    /// Exact-sample summary of every completion's end-to-end latency.
    pub fn latency_summary(&self) -> Summary {
        let mut s = Summary::new();
        for c in &self.completions {
            s.add(c.e2e_latency());
        }
        s
    }

    /// Output-token throughput over the experiment window (tokens/s).
    pub fn throughput_tokens_per_s(&self, duration_s: f64) -> f64 {
        if duration_s <= 0.0 {
            return 0.0;
        }
        let toks: usize = self.completions.iter().map(|c| c.output_tokens).sum();
        toks as f64 / duration_s
    }

    /// Completed requests per second.
    pub fn throughput_rps(&self, duration_s: f64) -> f64 {
        if duration_s <= 0.0 {
            return 0.0;
        }
        self.completions.len() as f64 / duration_s
    }

    /// Fraction of completions within the SLO (Fig. 11b's y-axis).
    pub fn slo_attainment(&self) -> f64 {
        if self.completions.is_empty() {
            return 1.0;
        }
        let ok = self
            .completions
            .iter()
            .filter(|c| c.e2e_latency() <= self.slo_latency_s)
            .count();
        ok as f64 / self.completions.len() as f64
    }

    /// `1 − slo_attainment()`.
    pub fn slo_violation_rate(&self) -> f64 {
        1.0 - self.slo_attainment()
    }

    /// Deterministic per-monitor metrics document (sorted keys, stable
    /// float formatting) — one row of the simulator's golden-replay JSON.
    pub fn metrics_json(&self, duration_s: f64) -> Json {
        let mut lat = self.latency_summary();
        json::obj(vec![
            ("completed", json::num(self.completions.len() as f64)),
            ("latency_mean_s", json::num(lat.mean())),
            ("latency_p95_s", json::num(lat.p95())),
            ("oom_events", json::num(self.total_oom as f64)),
            ("slo_attainment", json::num(self.slo_attainment())),
            ("throughput_tps", json::num(self.throughput_tokens_per_s(duration_s))),
        ])
    }

    // ---- controller feed (windowed) ---------------------------------------

    /// Violation rate over completions since the last `controller_view`.
    fn window_violation_rate(&self) -> f64 {
        let w = &self.completions[self.window_start..];
        if w.is_empty() {
            return 0.0;
        }
        let bad = w
            .iter()
            .filter(|c| c.e2e_latency() > self.slo_latency_s)
            .count();
        bad as f64 / w.len() as f64
    }

    /// Build the controller's tick input from cluster state + the window
    /// since the previous tick, then advance the window.
    pub fn controller_view(&mut self, cluster: &Cluster, wall_s: f64) -> ControllerInputs {
        let n = cluster.n().max(1);
        let vacancy =
            cluster.devices.iter().map(|d| d.vacancy_rate()).sum::<f64>() / n as f64;
        // hottest = max by (compute util, mem frac)
        let hottest = (0..cluster.n())
            .max_by(|&a, &b| {
                let ka = cluster.device(a).utilization(wall_s)
                    + cluster.device(a).mem_frac();
                let kb = cluster.device(b).utilization(wall_s)
                    + cluster.device(b).mem_frac();
                ka.partial_cmp(&kb).unwrap()
            })
            .unwrap_or(0);
        let view = ControllerInputs {
            vacancy_rate: vacancy,
            slo_violation_rate: self.window_violation_rate(),
            oom_events: self.oom_since_tick,
            hottest_device: hottest,
            hottest_compute_util: cluster.device(hottest).utilization(wall_s),
            hottest_mem_frac: cluster.device(hottest).mem_frac(),
        };
        self.window_start = self.completions.len();
        self.oom_since_tick = 0;
        view
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, GIB};

    fn done(id: u64, at: f64, lat: f64, toks: usize) -> Completion {
        Completion {
            request_id: id,
            arrival_s: at,
            finish_s: at + lat,
            prompt_tokens: 10,
            output_tokens: toks,
            class: crate::workload::SloClass::default(),
        }
    }

    #[test]
    fn fleet_inputs_window_aggregates_like_the_kernel() {
        let mut w = FleetInputs::default();
        w.add_instance(true, true, 10); // active, serving
        w.add_instance(true, false, 6); // draining: live, not accepting
        w.add_instance(false, false, 0); // retired
        w.add_instance(true, true, 0); // cold-started idle
        w.parked = 4;
        assert_eq!(w.live, 3);
        assert_eq!(w.accepting, 2);
        assert_eq!(w.outstanding, 16);
        // (16 outstanding + 4 parked) / 2 accepting
        assert_eq!(w.mean_outstanding(), 10.0);
        // no accepting instances: the denominator clamps to 1
        let empty = FleetInputs { parked: 3, ..Default::default() };
        assert_eq!(empty.mean_outstanding(), 3.0);
    }

    #[test]
    fn throughput_and_latency() {
        let mut m = Monitor::new(10.0);
        m.record(done(0, 0.0, 2.0, 50));
        m.record(done(1, 1.0, 4.0, 150));
        assert_eq!(m.throughput_tokens_per_s(10.0), 20.0);
        assert_eq!(m.throughput_rps(10.0), 0.2);
        assert_eq!(m.latency_summary().mean(), 3.0);
    }

    #[test]
    fn slo_attainment_counts_violations() {
        let mut m = Monitor::new(5.0);
        m.record(done(0, 0.0, 2.0, 10));
        m.record(done(1, 0.0, 9.0, 10));
        m.record(done(2, 0.0, 4.0, 10));
        m.record(done(3, 0.0, 6.0, 10));
        assert_eq!(m.slo_attainment(), 0.5);
        assert_eq!(m.slo_violation_rate(), 0.5);
    }

    #[test]
    fn empty_monitor_attains_trivially() {
        let m = Monitor::new(5.0);
        assert_eq!(m.slo_attainment(), 1.0);
        assert_eq!(m.throughput_tokens_per_s(10.0), 0.0);
    }

    #[test]
    fn controller_view_windows_reset() {
        let mut m = Monitor::new(5.0);
        let cl = Cluster::paper_testbed();
        m.record(done(0, 0.0, 9.0, 10)); // violation in window 1
        let v1 = m.controller_view(&cl, 10.0);
        assert_eq!(v1.slo_violation_rate, 1.0);
        // window 2 is clean
        m.record(done(1, 0.0, 1.0, 10));
        let v2 = m.controller_view(&cl, 10.0);
        assert_eq!(v2.slo_violation_rate, 0.0);
    }

    #[test]
    fn oom_events_flow_once() {
        let mut m = Monitor::new(5.0);
        let cl = Cluster::paper_testbed();
        m.record_oom();
        m.record_oom();
        assert_eq!(m.controller_view(&cl, 1.0).oom_events, 2);
        assert_eq!(m.controller_view(&cl, 1.0).oom_events, 0);
        assert_eq!(m.total_oom(), 2);
    }

    #[test]
    fn metrics_json_deterministic() {
        let mut m = Monitor::new(5.0);
        m.record(done(0, 0.0, 2.0, 50));
        m.record(done(1, 1.0, 9.0, 30));
        m.record_oom();
        let a = m.metrics_json(10.0).to_string();
        let b = m.metrics_json(10.0).to_string();
        assert_eq!(a, b);
        let j = crate::util::json::Json::parse(&a).unwrap();
        assert_eq!(j.req("completed").as_usize(), Some(2));
        assert_eq!(j.req("oom_events").as_usize(), Some(1));
        assert_eq!(j.req("slo_attainment").as_f64(), Some(0.5));
        assert_eq!(j.req("throughput_tps").as_f64(), Some(8.0));
    }

    #[test]
    fn hottest_device_by_load() {
        let mut m = Monitor::new(5.0);
        let mut cl = Cluster::paper_testbed();
        cl.device_mut(2).alloc("x", 30.0 * GIB).unwrap();
        cl.device_mut(2).add_busy(9.0);
        let v = m.controller_view(&cl, 10.0);
        assert_eq!(v.hottest_device, 2);
        assert!(v.hottest_mem_frac > 0.7);
        assert!(v.hottest_compute_util > 0.8);
        assert!(v.vacancy_rate > 0.5); // other three devices empty
    }
}
