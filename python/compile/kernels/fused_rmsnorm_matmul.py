"""Fused RMSNorm + matmul Pallas kernel.

Every decoder sub-module (QKV projection, FFN gate/up) begins with
`rmsnorm(x) @ W`. Fusing the normalization into the matmul's LHS load avoids
materializing the normalized activation in HBM — the same fusion the paper's
serving engines get from CUDA kernels, expressed here as a Pallas grid over
(row-blocks, col-blocks) with the row statistics computed once per row block
in VMEM.

interpret=True (CPU PJRT; see flash_attention.py). Oracle: ref.rmsnorm_matmul.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(x_ref, g_ref, w_ref, o_ref, *, eps: float, d_model: int):
    """Grid step: one [block_m, d] row panel × one [d, block_n] W panel.

    The RMS statistic is recomputed per (m, n) step; it is O(block_m * d)
    FLOPs against the O(block_m * d * block_n) matmul — cheap, and it keeps
    the kernel stateless across grid steps (no scratch semaphores needed).
    """
    x = x_ref[...].astype(jnp.float32)  # [block_m, d]
    g = g_ref[...].astype(jnp.float32)  # [d]
    w = w_ref[...].astype(jnp.float32)  # [d, block_n]
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    xn = x / rms * g[None, :]
    o_ref[...] = (xn @ w).astype(o_ref.dtype)  # MXU matmul


def fused_rmsnorm_matmul(x, gamma, w, *, block_m: int = 16,
                         block_n: int = 64, eps: float = 1e-6):
    """rmsnorm(x, gamma) @ w with the norm fused into the matmul.

    x: [..., m, d]; gamma: [d]; w: [d, n] → [..., m, n].
    Leading batch dims are flattened into rows (RMSNorm is row-local).
    """
    *lead, m, d = x.shape
    n = w.shape[1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    block_m = min(block_m, rows)
    block_n = min(block_n, n)

    kernel = functools.partial(_fused_kernel, eps=eps, d_model=d)
    grid = (pl.cdiv(rows, block_m), pl.cdiv(n, block_n))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=True,
    )(xf, gamma, w)
    return out.reshape(*lead, m, n)
