//! Fig. 3 — default vs migrate-1-layer latency under high load.
//!
//! Paper setup: one 13B instance on an A100, RPS 35–55. The default
//! deployment sits right at the memory margin: under load its KV pool
//! crosses device capacity, triggering OOM → reload → batch backoff (the
//! ~37 s cliff). "Migration #1" moves a single decoder layer (weights +
//! its KV share) to the spare device — ~0.85 GiB of relief that keeps the
//! instance on the safe side of the margin (paper: ~70% latency cut,
//! 11.2 s at 50–55 RPS).

use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::model::cost::CostModel;
use cocoserve::ops::{ModuleOps, PlanExecutor};
use cocoserve::placement::Placement;
use cocoserve::plan::ScalePlan;
use cocoserve::scheduler::SchedulerConfig;
use cocoserve::sim::{OomBehavior, SimConfig, SimPolicy, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const RPS: [f64; 5] = [35.0, 40.0, 45.0, 50.0, 55.0];
const CO_TENANT_GIB: f64 = 13.5;
const MAX_BATCH: usize = 48;

fn policy() -> SimPolicy {
    // "default configuration" of the paper's own engine: continuous
    // batching, but no module scaling — OOM means reload + backoff.
    SimPolicy {
        scheduler: SchedulerConfig::continuous(MAX_BATCH),
        paged_kv: true,
        autoscale: false,
        oom: OomBehavior::FailBatch,
    }
}

fn run(migrated: bool, rps: f64, seed: u64) -> (f64, u64) {
    let cfg = SimConfig::paper_13b();
    let mut cluster = Cluster::homogeneous(2, DeviceSpec::a100_40gb());
    cluster
        .device_mut(0)
        .alloc("co-tenant", CO_TENANT_GIB * GIB)
        .unwrap();
    let mut placement = Placement::single_device(cfg.model.n_layers, 0);
    if migrated {
        // Execute the actual migration plan on a scratch cluster to get
        // the migrated placement (Simulation::new deploys from the
        // placement).
        let cm = CostModel::new(cfg.model.clone());
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let mut scratch = Cluster::homogeneous(2, DeviceSpec::a100_40gb());
        ops.deploy_instance(&mut scratch, &placement).unwrap();
        PlanExecutor::new(&ops)
            .execute(&mut scratch, &mut placement, &ScalePlan::migrate_batch(&[39], 1))
            .unwrap();
    }
    let sim = Simulation::new(cfg, cluster, vec![(placement, policy())]);
    let trace = Trace::generate(Arrival::Poisson { rps }, LengthDist::alpaca(), 20.0, seed);
    let r = sim.run(&trace, 20.0);
    (r.merged_latency().mean(), r.total_oom_events)
}

fn main() {
    println!(
        "Fig. 3 — latency cliff: default vs migrate-1-layer \
         (13B, {CO_TENANT_GIB} GiB co-tenant, batch {MAX_BATCH})\n"
    );
    let mut t = Table::new(&["rps", "default lat(s)", "default OOM",
                             "migrated lat(s)", "migrated OOM", "reduction"]);
    let mut rep = Report::new("fig3_migration_cliff");
    let (mut def_s, mut mig_s) = (vec![], vec![]);
    for &rps in &RPS {
        let (d_lat, d_oom) = run(false, rps, 5);
        let (m_lat, m_oom) = run(true, rps, 5);
        def_s.push(d_lat);
        mig_s.push(m_lat);
        t.row(&[
            format!("{rps:.0}"),
            format!("{d_lat:.2}"),
            format!("{d_oom}"),
            format!("{m_lat:.2}"),
            format!("{m_oom}"),
            format!("{:.0}%", (1.0 - m_lat / d_lat) * 100.0),
        ]);
    }
    t.print();
    let hi = RPS.iter().position(|&r| r == 50.0).unwrap();
    println!(
        "\nat 50 RPS: default {:.1}s vs migrated {:.1}s — {:.0}% reduction \
         (paper: ~70% at 50–55 RPS)",
        def_s[hi],
        mig_s[hi],
        (1.0 - mig_s[hi] / def_s[hi]) * 100.0
    );
    rep.set("rps", json::arr(RPS.iter().map(|&x| json::num(x))));
    rep.series("default_latency_s", &def_s);
    rep.series("migrated_latency_s", &mig_s);
    println!("report: {}", rep.write().unwrap().display());
}
