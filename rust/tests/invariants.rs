//! Scheduler invariants, property-tested through the crate's public API
//! with the `util::prop` harness.
//!
//! The contracts under test are the ones the paper's serving layer leans
//! on:
//!
//! * **Fig. 4 batch splitting** — `split_batch` conserves the sequence
//!   count and never lets two replica shares differ by more than one
//!   (15 → 8/7 at degree 2).
//! * **Admission bound** — under both [`BatchPolicy`] variants the
//!   scheduler never runs more than `max_batch` sequences at once, and no
//!   step ever names more than `max_batch` requests.
//! * **Conservation** — every submitted request eventually completes:
//!   nothing is lost, nothing completes twice.

use cocoserve::scheduler::{split_batch, BatchPolicy, Scheduler, SchedulerConfig, Step};
use cocoserve::util::{prop, rng::Rng};
use cocoserve::workload::Request;

#[test]
fn prop_split_batch_conserves_and_balances() {
    prop::check(
        "split-batch-contract",
        |r: &mut Rng| (r.below(512) as usize, 1 + r.below(16) as usize),
        |&(batch, degree)| {
            let shares = split_batch(batch, degree);
            if shares.len() != degree {
                return Err(format!("expected {degree} shares, got {}", shares.len()));
            }
            if shares.iter().sum::<usize>() != batch {
                return Err(format!("sum {:?} != batch {batch}", shares));
            }
            let mx = *shares.iter().max().unwrap();
            let mn = *shares.iter().min().unwrap();
            if mx - mn > 1 {
                return Err(format!("shares differ by more than 1: {shares:?}"));
            }
            // earlier replicas take the remainder (deterministic order)
            let mut sorted = shares.clone();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            if sorted != shares {
                return Err(format!("remainder not front-loaded: {shares:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn split_batch_matches_fig4_example() {
    assert_eq!(split_batch(15, 2), vec![8, 7]);
}

/// Drive a scheduler to quiescence, checking the admission bound at every
/// step; returns the number of completed requests.
fn drive(cfg: SchedulerConfig, requests: &[(f64, usize)]) -> Result<u64, String> {
    let mut s = Scheduler::new(cfg);
    let mut pending = requests.to_vec();
    pending.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut submitted = 0usize;
    let mut now = 0.0f64;
    let mut guard = 0;
    loop {
        // submit everything that has "arrived" by now
        while submitted < pending.len() && pending[submitted].0 <= now {
            let (at, out) = pending[submitted];
            s.submit(Request {
                id: submitted as u64,
                arrival_s: at,
                prompt_tokens: 8,
                output_tokens: out,
                class: Default::default(),
            });
            submitted += 1;
        }
        if s.is_idle() && submitted >= pending.len() {
            return Ok(s.completed());
        }
        guard += 1;
        if guard > 100_000 {
            return Err("scheduler failed to quiesce".into());
        }
        now += 0.05;
        let step = s.next_step(now);
        let ids = match &step {
            Step::Prefill { request_ids } | Step::Decode { request_ids } => {
                request_ids.clone()
            }
            Step::Idle => continue,
        };
        // ---- admission bound: the step and the running set never exceed
        // max_batch, and every id the scheduler names is one we submitted
        // and is actually running.
        if ids.len() > s.cfg.max_batch {
            return Err(format!(
                "step of {} exceeds max_batch {}",
                ids.len(),
                s.cfg.max_batch
            ));
        }
        if s.running_len() > s.cfg.max_batch {
            return Err(format!(
                "running {} exceeds max_batch {}",
                s.running_len(),
                s.cfg.max_batch
            ));
        }
        if ids.iter().any(|id| *id >= submitted as u64) {
            return Err("scheduler named an unsubmitted request id".into());
        }
        let running: Vec<u64> = s.running_view().iter().map(|(id, _, _)| *id).collect();
        if ids.iter().any(|id| !running.contains(id)) {
            return Err("step ids not in running set".into());
        }
        // ---- execute the step (the engine's side of the contract)
        match step {
            Step::Prefill { request_ids } => s.on_prefilled(&request_ids),
            Step::Decode { request_ids } => s.on_decoded(&request_ids),
            Step::Idle => unreachable!(),
        }
    }
}

#[test]
fn prop_scheduler_admission_and_conservation() {
    prop::check(
        "scheduler-admission-conservation",
        |r: &mut Rng| {
            let n = 1 + r.below(40) as usize;
            let max_b = 1 + r.below(10) as usize;
            let continuous = r.f64() < 0.5;
            let reqs: Vec<(f64, usize)> = (0..n)
                .map(|_| (r.f64() * 3.0, 1 + r.below(6) as usize))
                .collect();
            (max_b, continuous, reqs)
        },
        |(max_b, continuous, reqs)| {
            let cfg = if *continuous {
                SchedulerConfig::continuous(*max_b)
            } else {
                SchedulerConfig::hft(*max_b)
            };
            let done = drive(cfg, reqs)?;
            if done != reqs.len() as u64 {
                return Err(format!("completed {done} != submitted {}", reqs.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn both_policies_respect_max_batch_exactly_at_the_boundary() {
    for cfg in [SchedulerConfig::continuous(3), SchedulerConfig::hft(3)] {
        let mut s = Scheduler::new(cfg);
        for i in 0..7 {
            s.submit(Request {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 8,
                output_tokens: 2,
                class: Default::default(),
            });
        }
        match s.next_step(10.0) {
            Step::Prefill { request_ids } => {
                assert_eq!(request_ids.len(), 3, "{:?}", cfg.policy);
            }
            other => panic!("{:?}: {other:?}", cfg.policy),
        }
        assert_eq!(s.running_len(), 3);
        assert_eq!(s.pending_len(), 4);
        assert!(matches!(cfg.policy, BatchPolicy::Continuous | BatchPolicy::Static { .. }));
    }
}
