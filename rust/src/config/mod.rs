//! Run configuration: CLI/JSON-file experiment descriptions.
//!
//! `cocoserve serve|sim ...` accepts either flags or `--config file.json`;
//! both construct a [`RunConfig`]. Kept deliberately small — library users
//! compose the typed configs (`SimConfig`, `ServeConfig`, policies)
//! directly; this is the launcher's surface.

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Which serving policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// HFT-like static baseline: fixed replicas, no dynamic scaling.
    Hft,
    /// vLLM-like baseline: continuous batching, instance-granular scaling.
    VllmLike,
    /// The paper's system: module-granular replication and migration.
    CoCoServe,
    /// CoCoServe with auto-scaling disabled (ablation).
    CoCoNoScale,
}

impl Policy {
    /// Parse a policy name as accepted by `--policy` (case-insensitive;
    /// `vllm`/`vllm-like` and `coco`/`cocoserve` are aliases).
    pub fn parse(s: &str) -> Result<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "hft" => Ok(Policy::Hft),
            "vllm" | "vllm-like" => Ok(Policy::VllmLike),
            "coco" | "cocoserve" => Ok(Policy::CoCoServe),
            "coco-noscale" => Ok(Policy::CoCoNoScale),
            other => Err(anyhow!("unknown policy `{other}` (hft|vllm|coco|coco-noscale)")),
        }
    }

    /// Canonical display name (the form `--policy` echoes back).
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Hft => "hft",
            Policy::VllmLike => "vllm-like",
            Policy::CoCoServe => "cocoserve",
            Policy::CoCoNoScale => "coco-noscale",
        }
    }

    /// Materialize the simulator policy bundle for this baseline.
    pub fn sim_policy(&self, max_batch: usize) -> crate::sim::SimPolicy {
        match self {
            Policy::Hft => crate::baselines::hft(max_batch),
            Policy::VllmLike => crate::baselines::vllm_like(max_batch),
            Policy::CoCoServe => crate::baselines::cocoserve(max_batch),
            Policy::CoCoNoScale => crate::baselines::cocoserve_no_autoscale(max_batch),
        }
    }
}

/// A launcher run description.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// "serve" (real tiny model), "sim" (paper-scale simulator) or
    /// "trace" (sim with telemetry on, exporting a Perfetto trace).
    pub mode: String,
    /// Serving policy under test.
    pub policy: Policy,
    /// Simulated model config ("llama2-13b" / "llama2-70b") or the real
    /// config to serve ("tiny-llama").
    pub model: String,
    /// Mean arrival rate in requests per second.
    pub rps: f64,
    /// Trace duration in simulated (or wall, for `serve`) seconds.
    pub duration_s: f64,
    /// Continuous-batching batch-size cap.
    pub max_batch: usize,
    /// Number of serving instances to deploy.
    pub instances: usize,
    /// Number of devices in the cluster.
    pub devices: usize,
    /// RNG seed for workload generation (and everything downstream).
    pub seed: u64,
    /// AOT artifact directory for `serve`/`inspect` (default: `artifacts/`).
    pub artifacts_dir: Option<String>,
    /// Traffic scenario for the `trace` command
    /// (steady|diurnal|burst|ramp|two_tenant).
    pub scenario: String,
    /// Output path for exported files (the `trace` command's JSON).
    pub out: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            mode: "sim".into(),
            policy: Policy::CoCoServe,
            model: "llama2-13b".into(),
            rps: 10.0,
            duration_s: 30.0,
            max_batch: 16,
            instances: 1,
            devices: 4,
            seed: 42,
            artifacts_dir: None,
            scenario: "steady".into(),
            out: None,
        }
    }
}

impl RunConfig {
    /// Build a config from a parsed JSON object; unknown keys are errors.
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        let obj = j.as_obj().context("config must be an object")?;
        for (k, v) in obj {
            match k.as_str() {
                "mode" => c.mode = v.as_str().context("mode")?.to_string(),
                "policy" => c.policy = Policy::parse(v.as_str().context("policy")?)?,
                "model" => c.model = v.as_str().context("model")?.to_string(),
                "rps" => c.rps = v.as_f64().context("rps")?,
                "duration_s" => c.duration_s = v.as_f64().context("duration_s")?,
                "max_batch" => c.max_batch = v.as_usize().context("max_batch")?,
                "instances" => c.instances = v.as_usize().context("instances")?,
                "devices" => c.devices = v.as_usize().context("devices")?,
                "seed" => c.seed = v.as_u64().context("seed")?,
                "artifacts_dir" => {
                    c.artifacts_dir = Some(v.as_str().context("artifacts_dir")?.to_string())
                }
                "scenario" => c.scenario = v.as_str().context("scenario")?.to_string(),
                "out" => c.out = Some(v.as_str().context("out")?.to_string()),
                other => return Err(anyhow!("unknown config key `{other}`")),
            }
        }
        Ok(c)
    }

    /// Load a config from a JSON file on disk.
    pub fn load(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("config json: {e}"))?;
        RunConfig::from_json(&j)
    }

    /// Apply a `--key value` CLI override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "mode" => self.mode = value.to_string(),
            "policy" => self.policy = Policy::parse(value)?,
            "model" => self.model = value.to_string(),
            "rps" => self.rps = value.parse().context("rps")?,
            "duration" | "duration_s" => self.duration_s = value.parse().context("duration")?,
            "max-batch" | "max_batch" => self.max_batch = value.parse().context("max_batch")?,
            "instances" => self.instances = value.parse().context("instances")?,
            "devices" => self.devices = value.parse().context("devices")?,
            "seed" => self.seed = value.parse().context("seed")?,
            "artifacts-dir" => self.artifacts_dir = Some(value.to_string()),
            "scenario" => self.scenario = value.to_string(),
            "out" => self.out = Some(value.to_string()),
            other => return Err(anyhow!("unknown flag --{other}")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = RunConfig::default();
        assert_eq!(c.policy, Policy::CoCoServe);
        assert_eq!(c.devices, 4);
    }

    #[test]
    fn json_roundtrip() {
        let j = Json::parse(
            r#"{"mode":"sim","policy":"hft","model":"llama2-70b",
                "rps":25,"duration_s":10,"max_batch":8,"instances":2,
                "devices":4,"seed":7}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.policy, Policy::Hft);
        assert_eq!(c.model, "llama2-70b");
        assert_eq!(c.rps, 25.0);
        assert_eq!(c.instances, 2);
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"nope": 1}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut c = RunConfig::default();
        c.set("policy", "vllm").unwrap();
        c.set("rps", "33.5").unwrap();
        c.set("max-batch", "4").unwrap();
        assert_eq!(c.policy, Policy::VllmLike);
        assert_eq!(c.rps, 33.5);
        assert_eq!(c.max_batch, 4);
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn trace_keys_roundtrip() {
        let j = Json::parse(r#"{"scenario":"burst","out":"t.json"}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.scenario, "burst");
        assert_eq!(c.out.as_deref(), Some("t.json"));
        let mut c = RunConfig::default();
        assert_eq!(c.scenario, "steady");
        assert!(c.out.is_none());
        c.set("scenario", "ramp").unwrap();
        c.set("out", "x.json").unwrap();
        assert_eq!(c.scenario, "ramp");
        assert_eq!(c.out.as_deref(), Some("x.json"));
    }

    #[test]
    fn policy_parse_aliases() {
        assert_eq!(Policy::parse("COCO").unwrap(), Policy::CoCoServe);
        assert_eq!(Policy::parse("vllm-like").unwrap(), Policy::VllmLike);
        assert!(Policy::parse("megatron").is_err());
    }
}
