//! The scenario library: named traffic shapes for multi-instance experiments.
//!
//! The paper evaluates under steady Poisson load only (§6.1); the systems it
//! is compared against are stressed by *dynamic* traffic — MorphServe swaps
//! under bursty traces, FlexPipe refactors inflight under fragmented,
//! fluctuating load. These constructors package the shapes the fig10/fig11
//! benches sweep so every scaling experiment runs the same five scenarios:
//!
//! * **steady**  — constant-rate Poisson (the paper's baseline shape),
//! * **diurnal** — sinusoidal day/night cycle (slow swing the scale-up
//!   loop should harvest and the scale-down loop should survive),
//! * **burst**   — a 3× spike window mid-run (flash crowd),
//! * **ramp**    — monotone growth from 20% to 180% of the target rate
//!   (capacity walk-up),
//! * **two-tenant** — interactive chat (short prompts, short outputs)
//!   mixed with batch summarization (long prompts, long outputs) at the
//!   same aggregate rate — the fragmented length mix that stresses
//!   continuous batching and KV accounting.
//!
//! All constructors are deterministic in `(rps, duration_s, seed)`.
//!
//! [`FailureSchedule`] extends the library to the *failure domain*: a
//! seed-deterministic list of device deaths (spot preemptions, hardware
//! loss) the chaos experiments inject into the kernel alongside any of
//! the traffic shapes above.

use super::{Arrival, LengthDist, Trace};
use crate::util::rng::Rng;

/// One scheduled device death.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceFailure {
    /// Simulated failure instant (seconds from experiment start).
    pub t: f64,
    /// The device that dies.
    pub device: usize,
}

/// A deterministic schedule of device failures for one run — the chaos
/// harness's ground truth. Each device fails at most once (there is no
/// resurrection), and failures are sorted by time so the kernel can seed
/// them as events up front.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FailureSchedule {
    /// The failures, ascending by time, one per device.
    pub failures: Vec<DeviceFailure>,
}

impl FailureSchedule {
    /// An explicit schedule from `(time, device)` pairs. Pairs are sorted
    /// by time; a device listed twice keeps only its earliest death.
    pub fn at(points: &[(f64, usize)]) -> FailureSchedule {
        let mut pts: Vec<(f64, usize)> = points.to_vec();
        pts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut seen = std::collections::BTreeSet::new();
        let failures = pts
            .into_iter()
            .filter(|&(_, d)| seen.insert(d))
            .map(|(t, device)| DeviceFailure { t, device })
            .collect();
        FailureSchedule { failures }
    }

    /// A seed-deterministic schedule: `count` failures drawn over the
    /// middle of the run (`[0.1, 0.9) · duration_s` — early enough that
    /// recovery is exercised, late enough that the fleet has deployed),
    /// each killing a distinct device from `targets` (typically
    /// [`crate::cluster::Cluster::preemptible_devices`]). `count` clamps
    /// to `targets.len()`; the same `(targets, duration_s, count, seed)`
    /// always yields the same schedule.
    pub fn seeded(
        targets: &[usize],
        duration_s: f64,
        count: usize,
        seed: u64,
    ) -> FailureSchedule {
        let mut rng = Rng::new(seed ^ 0xFA11);
        let mut pool: Vec<usize> = targets.to_vec();
        let mut points = Vec::new();
        for _ in 0..count.min(pool.len()) {
            let pick = rng.below(pool.len() as u64) as usize;
            let device = pool.swap_remove(pick);
            let t = duration_s * (0.1 + 0.8 * rng.f64());
            points.push((t, device));
        }
        FailureSchedule::at(&points)
    }

    /// Number of scheduled failures.
    pub fn len(&self) -> usize {
        self.failures.len()
    }

    /// Does the schedule contain no failures?
    pub fn is_empty(&self) -> bool {
        self.failures.is_empty()
    }
}

impl LengthDist {
    /// Interactive-chat tenant: short prompts, short replies.
    pub fn chat() -> LengthDist {
        LengthDist {
            prompt_mu: 2.7, // median ≈ 15 tokens
            prompt_sigma: 0.6,
            max_prompt: 256,
            mean_output: 32.0,
            max_new_tokens: 128,
        }
    }

    /// Batch-summarization tenant: long documents, long outputs.
    pub fn summarize() -> LengthDist {
        LengthDist {
            prompt_mu: 4.6, // median ≈ 100 tokens, heavy tail
            prompt_sigma: 0.6,
            max_prompt: 512,
            mean_output: 160.0,
            max_new_tokens: 256,
        }
    }
}

impl Trace {
    /// Steady Poisson arrivals at `rps` with Alpaca-like lengths.
    pub fn steady(rps: f64, duration_s: f64, seed: u64) -> Trace {
        Trace::generate(Arrival::Poisson { rps }, LengthDist::alpaca(), duration_s, seed)
    }

    /// Diurnal sine around `mean_rps` (amplitude 0.7, one full cycle over
    /// the run, so the trace exercises both crest and trough).
    pub fn diurnal(mean_rps: f64, duration_s: f64, seed: u64) -> Trace {
        Trace::generate(
            Arrival::Diurnal { mean: mean_rps, amplitude: 0.7, period_s: duration_s },
            LengthDist::alpaca(),
            duration_s,
            seed,
        )
    }

    /// Burst spike: base load at `rps` with a 3× window over the middle
    /// fifth of the run.
    pub fn burst(rps: f64, duration_s: f64, seed: u64) -> Trace {
        Trace::generate(
            Arrival::Burst {
                base: rps,
                burst: 3.0 * rps,
                start_s: 0.4 * duration_s,
                end_s: 0.6 * duration_s,
            },
            LengthDist::alpaca(),
            duration_s,
            seed,
        )
    }

    /// Ramp from 20% to 180% of `rps` over the run (mean ≈ `rps`).
    pub fn ramp(rps: f64, duration_s: f64, seed: u64) -> Trace {
        Trace::generate(
            Arrival::Ramp { from: 0.2 * rps, to: 1.8 * rps },
            LengthDist::alpaca(),
            duration_s,
            seed,
        )
    }

    /// Two-tenant mix at an aggregate `rps`: 70% interactive chat, 30%
    /// batch summarization, each with its own length distribution. Seeds
    /// are derived per-tenant so the mix is deterministic.
    pub fn two_tenant(rps: f64, duration_s: f64, seed: u64) -> Trace {
        let chat = Trace::generate(
            Arrival::Poisson { rps: 0.7 * rps },
            LengthDist::chat(),
            duration_s,
            seed ^ 0xC047,
        );
        let batch = Trace::generate(
            Arrival::Poisson { rps: 0.3 * rps },
            LengthDist::summarize(),
            duration_s,
            seed ^ 0xBA7C,
        );
        Trace::merge(vec![chat, batch])
    }

    /// The two-tenant mix with SLO classes attached: the interactive chat
    /// tenant is latency-sensitive, the batch summarization tenant is
    /// best-effort. Identical arrivals and lengths to
    /// [`Trace::two_tenant`] at the same `(rps, duration_s, seed)` — only
    /// the class tags differ — so classed and classless runs of the same
    /// scenario are directly comparable.
    pub fn two_tenant_classed(rps: f64, duration_s: f64, seed: u64) -> Trace {
        let chat = Trace::generate(
            Arrival::Poisson { rps: 0.7 * rps },
            LengthDist::chat(),
            duration_s,
            seed ^ 0xC047,
        )
        .with_class(super::SloClass::LatencySensitive);
        let batch = Trace::generate(
            Arrival::Poisson { rps: 0.3 * rps },
            LengthDist::summarize(),
            duration_s,
            seed ^ 0xBA7C,
        )
        .with_class(super::SloClass::BestEffort);
        Trace::merge(vec![chat, batch])
    }

    /// Burst spike with SLO classes: the base-load stream is
    /// latency-sensitive, the 3× mid-run spike is best-effort backfill —
    /// the flash-crowd shape where a premium tenant must ride out a
    /// throughput tenant's surge. Deterministic in `(rps, duration_s,
    /// seed)`.
    pub fn burst_classed(rps: f64, duration_s: f64, seed: u64) -> Trace {
        let premium = Trace::generate(
            Arrival::Poisson { rps },
            LengthDist::chat(),
            duration_s,
            seed ^ 0x51_0,
        )
        .with_class(super::SloClass::LatencySensitive);
        let surge = Trace::generate(
            Arrival::Burst {
                base: 0.2 * rps,
                burst: 3.0 * rps,
                start_s: 0.4 * duration_s,
                end_s: 0.6 * duration_s,
            },
            LengthDist::summarize(),
            duration_s,
            seed ^ 0xBE_0,
        )
        .with_class(super::SloClass::BestEffort);
        Trace::merge(vec![premium, surge])
    }

    /// The full scenario sweep at a common target rate — what the
    /// fig10/fig11 benches iterate.
    pub fn scenario_sweep(rps: f64, duration_s: f64, seed: u64) -> Vec<(&'static str, Trace)> {
        vec![
            ("steady", Trace::steady(rps, duration_s, seed)),
            ("diurnal", Trace::diurnal(rps, duration_s, seed)),
            ("burst", Trace::burst(rps, duration_s, seed)),
            ("ramp", Trace::ramp(rps, duration_s, seed)),
            ("two-tenant", Trace::two_tenant(rps, duration_s, seed)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_scenarios_deterministically() {
        let a = Trace::scenario_sweep(15.0, 30.0, 9);
        let b = Trace::scenario_sweep(15.0, 30.0, 9);
        assert_eq!(a.len(), 5);
        for ((name_a, ta), (name_b, tb)) in a.iter().zip(&b) {
            assert_eq!(name_a, name_b);
            assert_eq!(ta.requests, tb.requests, "{name_a} not deterministic");
            assert!(!ta.is_empty(), "{name_a} generated no requests");
        }
        let names: Vec<_> = a.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, ["steady", "diurnal", "burst", "ramp", "two-tenant"]);
    }

    #[test]
    fn two_tenant_mixes_length_regimes() {
        let t = Trace::two_tenant(20.0, 60.0, 3);
        let long_prompts = t.requests.iter().filter(|r| r.prompt_tokens > 64).count();
        let short_prompts = t.requests.iter().filter(|r| r.prompt_tokens <= 32).count();
        assert!(long_prompts > t.len() / 10, "batch tenant missing: {long_prompts}");
        assert!(short_prompts > t.len() / 3, "chat tenant missing: {short_prompts}");
        // aggregate rate ≈ requested
        let rps = t.mean_rps(60.0);
        assert!((rps - 20.0).abs() < 3.0, "rps {rps}");
    }

    #[test]
    fn classed_two_tenant_matches_classless_payloads() {
        use crate::workload::SloClass;
        let classed = Trace::two_tenant_classed(20.0, 60.0, 3);
        let classless = Trace::two_tenant(20.0, 60.0, 3);
        // identical arrivals/lengths — only the class tags differ
        let strip = |t: &Trace| -> Vec<(u64, u64, usize, usize)> {
            t.requests
                .iter()
                .map(|r| (r.id, r.arrival_s.to_bits(), r.prompt_tokens, r.output_tokens))
                .collect()
        };
        assert_eq!(strip(&classed), strip(&classless));
        let premium = classed.count_class(SloClass::LatencySensitive);
        let be = classed.count_class(SloClass::BestEffort);
        assert!(premium > 0 && be > 0, "both tenants present: {premium}/{be}");
        assert!(premium > be, "chat tenant carries 70% of the rate");
        // classless variant is uniformly best-effort
        assert_eq!(classless.count_class(SloClass::BestEffort), classless.len());
    }

    #[test]
    fn classed_burst_concentrates_best_effort_in_window() {
        use crate::workload::SloClass;
        let t = Trace::burst_classed(10.0, 50.0, 4);
        let be_in_window = t
            .requests
            .iter()
            .filter(|r| {
                r.class == SloClass::BestEffort && (20.0..30.0).contains(&r.arrival_s)
            })
            .count();
        let be_total = t.count_class(SloClass::BestEffort);
        assert!(be_total > 0 && t.count_class(SloClass::LatencySensitive) > 0);
        assert!(
            be_in_window as f64 > 0.5 * be_total as f64,
            "best-effort surge must concentrate mid-run: {be_in_window}/{be_total}"
        );
    }

    #[test]
    fn burst_triples_mid_window_rate() {
        let t = Trace::burst(10.0, 50.0, 4);
        let during = t.requests.iter()
            .filter(|r| (20.0..30.0).contains(&r.arrival_s))
            .count() as f64 / 10.0;
        let outside = t.requests.iter()
            .filter(|r| !(20.0..30.0).contains(&r.arrival_s))
            .count() as f64 / 40.0;
        assert!(during > 2.0 * outside, "burst {during} vs base {outside}");
    }

    #[test]
    fn ramp_mean_near_target() {
        let t = Trace::ramp(20.0, 60.0, 5);
        let rps = t.mean_rps(60.0);
        assert!((rps - 20.0).abs() < 4.0, "rps {rps}");
    }

    #[test]
    fn failure_schedule_is_seed_deterministic_and_sorted() {
        let a = FailureSchedule::seeded(&[0, 1, 2, 3], 60.0, 3, 91);
        let b = FailureSchedule::seeded(&[0, 1, 2, 3], 60.0, 3, 91);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        for w in a.failures.windows(2) {
            assert!(w[1].t >= w[0].t, "unsorted schedule");
            assert_ne!(w[1].device, w[0].device, "device died twice");
        }
        for f in &a.failures {
            assert!((6.0..54.0).contains(&f.t), "failure at {} outside window", f.t);
        }
        let c = FailureSchedule::seeded(&[0, 1, 2, 3], 60.0, 3, 92);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn failure_schedule_clamps_count_and_dedups_devices() {
        let s = FailureSchedule::seeded(&[0, 1], 30.0, 5, 7);
        assert_eq!(s.len(), 2, "count clamps to the target pool");
        let explicit = FailureSchedule::at(&[(9.0, 1), (4.0, 0), (2.0, 1)]);
        assert_eq!(
            explicit.failures,
            vec![
                DeviceFailure { t: 2.0, device: 1 },
                DeviceFailure { t: 4.0, device: 0 },
            ],
            "sorted by time, earliest death per device wins"
        );
        assert!(FailureSchedule::default().is_empty());
    }

    // ---- property tests: the forecaster's ground truth ---------------------
    //
    // The predictive control plane is evaluated against these traces
    // (benches/fig12_predictive.rs), so the scenario library itself must
    // be deterministic, order-preserving under merge, and periodic where
    // it claims to be.

    use crate::util::{prop, rng::Rng};

    /// Fingerprint a trace cheaply but collision-sensitively.
    fn fingerprint(t: &Trace) -> (usize, u64) {
        let mut acc = 0u64;
        for r in &t.requests {
            acc = acc
                .wrapping_mul(0x100000001B3)
                .wrapping_add(r.arrival_s.to_bits())
                .wrapping_add((r.prompt_tokens * 31 + r.output_tokens) as u64);
        }
        (t.len(), acc)
    }

    #[test]
    fn prop_every_constructor_is_deterministic_per_seed() {
        prop::check(
            "scenario-deterministic",
            |r: &mut Rng| {
                let rps = 2.0 + r.f64() * 28.0;
                let dur = 5.0 + r.f64() * 40.0;
                let seed = r.next_u64();
                (rps, dur, seed)
            },
            |&(rps, dur, seed)| {
                let build = |which: usize| match which {
                    0 => Trace::steady(rps, dur, seed),
                    1 => Trace::diurnal(rps, dur, seed),
                    2 => Trace::burst(rps, dur, seed),
                    3 => Trace::ramp(rps, dur, seed),
                    _ => Trace::two_tenant(rps, dur, seed),
                };
                for which in 0..5 {
                    let a = build(which);
                    let b = build(which);
                    if a.requests != b.requests {
                        return Err(format!("constructor {which} not deterministic"));
                    }
                    if fingerprint(&a) != fingerprint(&b) {
                        return Err(format!("constructor {which} fingerprint drifted"));
                    }
                    // arrivals must be non-decreasing and in-window
                    for w in a.requests.windows(2) {
                        if w[1].arrival_s < w[0].arrival_s {
                            return Err(format!("constructor {which} unsorted"));
                        }
                    }
                    if a.requests.iter().any(|q| q.arrival_s < 0.0 || q.arrival_s >= dur) {
                        return Err(format!("constructor {which} out-of-window arrival"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_merge_preserves_tenant_counts_and_global_order() {
        prop::check(
            "merge-conservation",
            |r: &mut Rng| {
                let n_parts = 2 + r.below(4) as usize;
                let seeds: Vec<u64> = (0..n_parts).map(|_| r.next_u64()).collect();
                let rps = 2.0 + r.f64() * 15.0;
                (seeds, rps)
            },
            |(seeds, rps)| {
                // tag tenants by construction: each part uses a distinct
                // length regime so its requests stay identifiable by the
                // (prompt, output) payload multiset after the merge
                let parts: Vec<Trace> = seeds
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| {
                        let dist = if i % 2 == 0 {
                            super::LengthDist::chat()
                        } else {
                            super::LengthDist::summarize()
                        };
                        Trace::generate(Arrival::Poisson { rps: *rps }, dist, 12.0, s)
                    })
                    .collect();
                let per_tenant: Vec<usize> = parts.iter().map(|t| t.len()).collect();
                let total: usize = per_tenant.iter().sum();
                let mut payloads: Vec<(u64, usize, usize)> = parts
                    .iter()
                    .flat_map(|t| t.requests.iter())
                    .map(|q| (q.arrival_s.to_bits(), q.prompt_tokens, q.output_tokens))
                    .collect();
                payloads.sort_unstable();

                let merged = Trace::merge(parts);
                if merged.len() != total {
                    return Err(format!("lost requests: {} != {total}", merged.len()));
                }
                // global arrival-time ordering
                for w in merged.requests.windows(2) {
                    if w[1].arrival_s < w[0].arrival_s {
                        return Err("merge broke arrival ordering".into());
                    }
                }
                // ids reassigned densely
                for (i, q) in merged.requests.iter().enumerate() {
                    if q.id != i as u64 {
                        return Err(format!("id {} at position {i}", q.id));
                    }
                }
                // per-tenant conservation: the payload multiset survives
                let mut merged_payloads: Vec<(u64, usize, usize)> = merged
                    .requests
                    .iter()
                    .map(|q| (q.arrival_s.to_bits(), q.prompt_tokens, q.output_tokens))
                    .collect();
                merged_payloads.sort_unstable();
                if merged_payloads != payloads {
                    return Err("merge changed some request payload".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_diurnal_respects_its_configured_period() {
        prop::check(
            "diurnal-period",
            |r: &mut Rng| {
                let mean = 12.0 + r.f64() * 20.0;
                let period = 16.0 + r.f64() * 16.0;
                let cycles = 2 + r.below(2) as usize;
                let seed = r.next_u64();
                (mean, period, cycles, seed)
            },
            |&(mean, period, cycles, seed)| {
                let dur = period * cycles as f64;
                let t = Trace::generate(
                    Arrival::Diurnal { mean, amplitude: 0.8, period_s: period },
                    super::LengthDist::alpaca(),
                    dur,
                    seed,
                );
                // every cycle's crest half must out-arrive its trough half
                for c in 0..cycles {
                    let base = c as f64 * period;
                    let crest = t
                        .requests
                        .iter()
                        .filter(|q| (base..base + period / 2.0).contains(&q.arrival_s))
                        .count();
                    let trough = t
                        .requests
                        .iter()
                        .filter(|q| {
                            (base + period / 2.0..base + period).contains(&q.arrival_s)
                        })
                        .count();
                    if crest <= trough {
                        return Err(format!(
                            "cycle {c}: crest {crest} !> trough {trough} (period {period:.1})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
