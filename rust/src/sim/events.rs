//! The discrete-event queue driving the simulation kernel.
//!
//! A binary heap of timestamped events with **fully deterministic
//! ordering**: events pop by ascending time, then by kind priority
//! (arrivals before their routing deliveries before forecast ticks
//! before controller ticks before scaling-op starts/completions before
//! step completions before wake-ups — routing delivers before a
//! coinciding forecast tick closes its rate buckets, the forecast closes
//! before a coinciding controller tick consumes it, and scaling ops
//! apply before a coinciding step completion so the step's successor
//! sees the post-op placement), then by instance
//! id, then by insertion sequence. Two runs
//! over the same trace therefore process an identical event sequence,
//! which is what makes the golden-replay test (byte-identical metrics
//! JSON) possible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The `idx`-th trace request reaches the router.
    Arrival { request_idx: usize },
    /// The coordinator routed trace request `request_idx` to `instance`;
    /// delivery (scheduler submission) happens when this event fires.
    /// Routed orders directly after Arrival so a routing decision made at
    /// an arrival's timestamp delivers before any same-time controller
    /// tick or step completion observes the queue.
    Routed { request_idx: usize, instance: usize },
    /// The predictive control plane advances its rate buckets to now.
    /// Scheduled only when a predictor is configured, at the controller
    /// period. Priority-slotted after `Routed` and before
    /// `ControllerTick`: a forecast closed at time t has seen every
    /// arrival routed at ≤ t, and a coinciding controller tick consumes
    /// *this* tick's forecast, never last period's.
    ForecastTick,
    /// The §5 controller evaluates every autoscaling instance.
    ControllerTick,
    /// Op `op_idx` of instance `instance`'s in-flight [`crate::plan::ScalePlan`]
    /// finishes: its ledger + placement effects apply now — this is what
    /// makes scaling overlap serving instead of pausing it. Completions
    /// order before starts so an abort invalidates the next op's start
    /// event (epoch bump) before it fires at the same instant.
    OpCompleted { instance: usize, op_idx: usize, epoch: u64 },
    /// Op `op_idx` begins its transfer. `epoch` guards against events of
    /// an aborted/superseded plan (stale epochs are ignored).
    OpStarted { instance: usize, op_idx: usize, epoch: u64 },
    /// Instance `instance` finishes the in-flight step started as its
    /// `token`-th step (stale completions — e.g. after an OOM rebuild
    /// cleared the step — carry an old token and are ignored).
    StepComplete { instance: usize, token: u64 },
    /// Re-poll instance `instance` (static-batch timeout or OOM backoff).
    Wake { instance: usize },
}

impl EventKind {
    /// Precedence among same-time events (lower pops first).
    fn priority(&self) -> u8 {
        match self {
            EventKind::Arrival { .. } => 0,
            EventKind::Routed { .. } => 1,
            EventKind::ForecastTick => 2,
            EventKind::ControllerTick => 3,
            EventKind::OpCompleted { .. } => 4,
            EventKind::OpStarted { .. } => 5,
            EventKind::StepComplete { .. } => 6,
            EventKind::Wake { .. } => 7,
        }
    }

    /// Instance tie-break key (non-instance events sort first).
    fn instance_key(&self) -> usize {
        match self {
            EventKind::Arrival { .. }
            | EventKind::ForecastTick
            | EventKind::ControllerTick => 0,
            EventKind::Routed { instance, .. }
            | EventKind::OpCompleted { instance, .. }
            | EventKind::OpStarted { instance, .. }
            | EventKind::StepComplete { instance, .. }
            | EventKind::Wake { instance } => *instance,
        }
    }
}

/// A scheduled event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Simulated firing time (seconds).
    pub time: f64,
    /// What fires.
    pub kind: EventKind,
    /// Monotone insertion counter — the final FIFO tie-break.
    seq: u64,
}

impl Event {
    fn key(&self) -> (f64, u8, usize, u64) {
        (self.time, self.kind.priority(), self.kind.instance_key(), self.seq)
    }
}

/// Min-heap wrapper (BinaryHeap is a max-heap, so the ordering is reversed).
#[derive(Debug)]
struct HeapEntry(Event);

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        let (ta, pa, ia, sa) = self.0.key();
        let (tb, pb, ib, sb) = other.0.key();
        // reversed: the greatest heap entry is the earliest event
        tb.total_cmp(&ta)
            .then(pb.cmp(&pa))
            .then(ib.cmp(&ia))
            .then(sb.cmp(&sa))
    }
}

/// Deterministic time-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<HeapEntry>,
    next_seq: u64,
}

impl EventQueue {
    /// An empty queue.
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `kind` to fire at `time` (must be finite).
    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "event at non-finite time");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry(Event { time, kind, seq }));
    }

    /// Pop the earliest event (ties broken as the module docs describe).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|e| e.0)
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.time)
    }

    /// Events currently scheduled.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Is nothing scheduled?
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut EventQueue) -> Vec<Event> {
        let mut v = vec![];
        while let Some(e) = q.pop() {
            v.push(e);
        }
        v
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::ControllerTick);
        q.push(1.0, EventKind::Arrival { request_idx: 0 });
        q.push(2.0, EventKind::StepComplete { instance: 0, token: 1 });
        let times: Vec<f64> = drain(&mut q).iter().map(|e| e.time).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn same_time_orders_by_kind_priority() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::Wake { instance: 0 });
        q.push(5.0, EventKind::StepComplete { instance: 0, token: 1 });
        q.push(5.0, EventKind::ControllerTick);
        q.push(5.0, EventKind::Routed { request_idx: 7, instance: 0 });
        q.push(5.0, EventKind::Arrival { request_idx: 7 });
        q.push(5.0, EventKind::ForecastTick);
        q.push(5.0, EventKind::OpCompleted { instance: 0, op_idx: 0, epoch: 1 });
        q.push(5.0, EventKind::OpStarted { instance: 0, op_idx: 1, epoch: 1 });
        let kinds: Vec<EventKind> = drain(&mut q).iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::Arrival { request_idx: 7 },
                EventKind::Routed { request_idx: 7, instance: 0 },
                EventKind::ForecastTick,
                EventKind::ControllerTick,
                EventKind::OpCompleted { instance: 0, op_idx: 0, epoch: 1 },
                EventKind::OpStarted { instance: 0, op_idx: 1, epoch: 1 },
                EventKind::StepComplete { instance: 0, token: 1 },
                EventKind::Wake { instance: 0 },
            ]
        );
    }

    #[test]
    fn same_time_same_kind_orders_by_instance_then_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::StepComplete { instance: 2, token: 1 });
        q.push(1.0, EventKind::StepComplete { instance: 0, token: 4 });
        q.push(1.0, EventKind::StepComplete { instance: 0, token: 9 });
        let popped = drain(&mut q);
        assert_eq!(popped[0].kind, EventKind::StepComplete { instance: 0, token: 4 });
        assert_eq!(popped[1].kind, EventKind::StepComplete { instance: 0, token: 9 });
        assert_eq!(popped[2].kind, EventKind::StepComplete { instance: 2, token: 1 });
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::ControllerTick);
        q.push(1.0, EventKind::ControllerTick);
        assert_eq!(q.pop().unwrap().time, 1.0);
        q.push(0.5, EventKind::Wake { instance: 3 });
        q.push(3.0, EventKind::ControllerTick);
        assert_eq!(q.pop().unwrap().time, 0.5);
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn determinism_across_identical_push_sequences() {
        let build = || {
            let mut q = EventQueue::new();
            for i in 0..50 {
                let t = (i * 7 % 13) as f64 * 0.5;
                q.push(t, EventKind::StepComplete { instance: i % 4, token: i as u64 });
                q.push(t, EventKind::Wake { instance: (i + 1) % 4 });
            }
            q
        };
        let a: Vec<(f64, EventKind)> =
            drain(&mut build()).iter().map(|e| (e.time, e.kind)).collect();
        let b: Vec<(f64, EventKind)> =
            drain(&mut build()).iter().map(|e| (e.time, e.kind)).collect();
        assert_eq!(a, b);
    }
}
