//! Fig. 15 (extension) — multi-tenant SLO classes: priority routing,
//! mid-step preemption, and weighted fairness vs. over-provisioning.
//!
//! Three deployments serve identical class-tagged traces:
//!
//! * **overprovision** — the classless baseline sized for the peak: all
//!   eight devices pinned from t=0, `KvHeadroom` routing, no class
//!   machinery. Premium latency is protected by brute capacity.
//! * **classed-strict** — an elastic 2→8 fleet under `StrictPriority`:
//!   premium requests route and drain first, all-best-effort batches are
//!   preempted at token boundaries when a premium request arrives, and
//!   reactive + predictive capacity planning run premium-first.
//! * **classed-wfq** — the same elastic fleet under `WeightedFair`
//!   (3:1 premium:best-effort) with a best-effort admission cap, the
//!   posture that still guarantees best-effort forward progress.
//!
//! Asserted per the issue's acceptance bar:
//! (a) both classed deployments hold the premium p99 SLO through the
//!     best-effort surge;
//! (b) best-effort absorbs the slack — premium SLO attainment is at
//!     least best-effort attainment on the burst scenario;
//! (c) each classed deployment spends strictly fewer device-seconds
//!     than over-provisioning;
//! (d) classless goldens stay additive-key clean: the over-provisioned
//!     run on the tagged two-tenant trace is byte-identical to the same
//!     run on its payload-equal untagged twin, and carries no `slo` key;
//! (e) every cell golden-replays byte-identically.
//!
//! ```bash
//! cargo bench --bench fig15_slo_classes                 # full sweep
//! FIG15_SMOKE=1 cargo bench --bench fig15_slo_classes   # CI smoke
//! GOLDEN_OUT=slo.json cargo bench --bench fig15_slo_classes
//! ```
//!
//! `GOLDEN_OUT=<path>` writes the classless goldens (tagged trace and
//! untagged twin); CI runs the smoke twice and byte-compares the two
//! files — the file-level half of the additive-key guarantee that (d)
//! asserts in-process.

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::coordinator::{FleetConfig, RoutePolicy, RouterConfig};
use cocoserve::forecast::PredictConfig;
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::util::stats::P2Quantile;
use cocoserve::workload::{SloClass, Trace};

const N_DEVICES: usize = 8;
const SEED_INSTANCES: usize = 2;
const SEED: u64 = 150;
/// The premium class's latency SLO.
const SLO_S: f64 = 20.0;

struct BenchShape {
    rps: f64,
    duration_s: f64,
    smoke: bool,
}

impl BenchShape {
    fn from_env() -> BenchShape {
        let smoke = std::env::var("FIG15_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
            || std::env::args().any(|a| a == "--smoke");
        if smoke {
            BenchShape { rps: 10.0, duration_s: 48.0, smoke }
        } else {
            BenchShape { rps: 12.0, duration_s: 72.0, smoke }
        }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Overprovision,
    ClassedStrict,
    ClassedWfq,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Overprovision => "overprovision",
            Mode::ClassedStrict => "classed-strict",
            Mode::ClassedWfq => "classed-wfq",
        }
    }

    fn class_aware(self) -> bool {
        self != Mode::Overprovision
    }
}

fn sim_config() -> SimConfig {
    let mut cfg = SimConfig::paper_13b();
    cfg.slo_latency_s = SLO_S;
    cfg
}

fn policy() -> SimPolicy {
    baselines::cocoserve(32)
}

fn setup(mode: Mode) -> FleetSetup {
    match mode {
        // peak-sized fixed fleet, classless routing, no class machinery
        Mode::Overprovision => FleetSetup {
            router: RouterConfig {
                policy: RoutePolicy::KvHeadroom,
                admission_limit: None,
                reroute_on_shed: true,
                ..RouterConfig::default()
            },
            ..Default::default()
        },
        Mode::ClassedStrict | Mode::ClassedWfq => {
            let mut fleet = FleetConfig::elastic(SEED_INSTANCES, N_DEVICES, policy());
            fleet.scale_out_queue = 20.0;
            fleet.cooldown_ticks = 2;
            fleet.idle_ticks_before_drain = 2;
            FleetSetup {
                router: RouterConfig {
                    policy: if mode == Mode::ClassedStrict {
                        RoutePolicy::StrictPriority
                    } else {
                        RoutePolicy::WeightedFair
                    },
                    admission_limit: None,
                    be_admission_limit: Some(48),
                    reroute_on_shed: true,
                    ..RouterConfig::default()
                },
                fleet: Some(fleet),
                controller: cocoserve::autoscale::ControllerConfig {
                    t_up: 2.0,
                    ..Default::default()
                },
                predictor: Some(PredictConfig::default()),
            }
        }
    }
}

fn run(mode: Mode, trace: &Trace, duration_s: f64) -> SimReport {
    let cfg = sim_config();
    let cluster = Cluster::homogeneous(N_DEVICES, DeviceSpec::a100_40gb());
    // over-provisioning pins one instance per device for the whole run;
    // the classed fleets seed two instances and scale elastically
    let n_seed = match mode {
        Mode::Overprovision => N_DEVICES,
        Mode::ClassedStrict | Mode::ClassedWfq => SEED_INSTANCES,
    };
    let placements: Vec<_> = (0..n_seed)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy()))
        .collect();
    Simulation::with_fleet(cfg, cluster, placements, setup(mode)).run(trace, duration_s)
}

/// p99 end-to-end latency over one class's completions (P² streaming
/// estimator — the same O(1)-memory percentile path the monitors use).
fn class_p99(r: &SimReport, class: SloClass) -> f64 {
    let mut p = P2Quantile::new(0.99);
    for m in &r.monitors {
        for c in m.completions() {
            if c.class == class {
                p.add(c.e2e_latency());
            }
        }
    }
    p.value()
}

fn main() {
    let shape = BenchShape::from_env();
    let golden_out = std::env::var("GOLDEN_OUT").ok().filter(|p| !p.is_empty());
    println!(
        "Fig. 15 — SLO classes vs over-provisioning, {N_DEVICES}×A100, \
         {:.0} rps premium base, {:.0}s, premium SLO ≤ {SLO_S:.0}s{}\n",
        shape.rps,
        shape.duration_s,
        if shape.smoke { " (SMOKE)" } else { "" }
    );

    let scenarios: Vec<(&str, Trace)> = vec![
        (
            "burst_classed",
            Trace::burst_classed(shape.rps, shape.duration_s, SEED),
        ),
        (
            "two_tenant_classed",
            Trace::two_tenant_classed(shape.rps, shape.duration_s, SEED),
        ),
    ];

    let mut table = Table::new(&[
        "scenario", "mode", "prem p99", "prem SLO%", "be SLO%", "preempt", "dev·s",
        "completed",
    ]);
    let mut rep = Report::new("fig15_slo_classes");
    let mut replay_ok = true;
    let mut dump = String::new();

    for (name, trace) in &scenarios {
        let mut cells = Vec::new();
        for mode in [Mode::Overprovision, Mode::ClassedStrict, Mode::ClassedWfq] {
            let r = run(mode, trace, shape.duration_s);
            // (e) golden replay per cell
            let again = run(mode, trace, shape.duration_s);
            let rj = r.to_json().to_string();
            let identical = rj == again.to_json().to_string();
            replay_ok &= identical;
            if !identical {
                eprintln!("WARNING: {name}/{} not replay-deterministic", mode.name());
            }

            // the slo block is exactly as additive as the routing policy
            assert_eq!(
                r.slo.is_some(),
                mode.class_aware(),
                "{name}/{}: slo block presence must track class-awareness",
                mode.name()
            );

            let prem_p99 = class_p99(&r, SloClass::LatencySensitive);
            let overall_att = r.slo_attainment();
            let (prem_att, be_att, preempt) = r.slo.map_or((f64::NAN, f64::NAN, 0), |s| {
                (s.premium_slo_attainment, s.be_slo_attainment, s.preemptions)
            });
            table.row(&[
                name.to_string(),
                mode.name().to_string(),
                format!("{prem_p99:.2}s"),
                if prem_att.is_nan() { "-".into() } else { format!("{:.1}", prem_att * 100.0) },
                if be_att.is_nan() { "-".into() } else { format!("{:.1}", be_att * 100.0) },
                if mode.class_aware() { preempt.to_string() } else { "-".into() },
                format!("{:.0}", r.device_seconds),
                r.total_completed().to_string(),
            ]);
            rep.set(
                &format!("{name}_{}", mode.name()),
                json::obj(vec![
                    ("premium_p99_s", json::num(prem_p99)),
                    (
                        "premium_slo_attainment",
                        json::num(if prem_att.is_nan() { overall_att } else { prem_att }),
                    ),
                    (
                        "be_slo_attainment",
                        json::num(if be_att.is_nan() { overall_att } else { be_att }),
                    ),
                    ("preemptions", json::num(preempt as f64)),
                    ("device_seconds", json::num(r.device_seconds)),
                    ("completed", json::num(r.total_completed() as f64)),
                    ("replay_deterministic", json::num(f64::from(u8::from(identical)))),
                ]),
            );
            if golden_out.is_some() && mode == Mode::Overprovision {
                dump.push_str(name);
                dump.push('\n');
                dump.push_str(&rj);
                dump.push('\n');
            }
            cells.push((mode, r));
        }

        let over = &cells[0].1;
        for (mode, r) in &cells[1..] {
            let prem_p99 = class_p99(r, SloClass::LatencySensitive);
            // (a) premium holds its p99 SLO through the surge
            assert!(
                prem_p99 <= SLO_S,
                "{name}/{}: premium p99 {prem_p99:.2}s blew the {SLO_S:.0}s SLO",
                mode.name()
            );
            // (c) at strictly lower spend than over-provisioning
            assert!(
                r.device_seconds < over.device_seconds,
                "{name}/{}: {:.1} dev·s must be strictly below over-provisioned {:.1}",
                mode.name(),
                r.device_seconds,
                over.device_seconds
            );
            let s = r.slo.expect("class-aware cell carries the slo block");
            assert!(s.premium_completed > 0, "{name}/{}: no premium completions", mode.name());
            assert!(s.be_completed > 0, "{name}/{}: no best-effort completions", mode.name());
            // (b) the slack lands on the best-effort class, not premium
            if *name == "burst_classed" {
                assert!(
                    s.premium_slo_attainment >= s.be_slo_attainment,
                    "{name}/{}: premium attainment {:.4} fell below best-effort {:.4}",
                    mode.name(),
                    s.premium_slo_attainment,
                    s.be_slo_attainment
                );
            }
        }
    }

    // (d) additive-key guarantee, in-process half: the classless baseline
    // on the tagged two-tenant trace is byte-identical to the same run on
    // its payload-equal untagged twin, and neither document has `slo`
    let tagged_trace = Trace::two_tenant_classed(shape.rps, shape.duration_s, SEED);
    let untagged_trace = Trace::two_tenant(shape.rps, shape.duration_s, SEED);
    let tagged = run(Mode::Overprovision, &tagged_trace, shape.duration_s)
        .to_json()
        .to_string();
    let untagged = run(Mode::Overprovision, &untagged_trace, shape.duration_s)
        .to_json()
        .to_string();
    assert_eq!(
        tagged, untagged,
        "a classless deployment must never observe the class tags"
    );
    assert!(
        !tagged.contains("\"slo\":"),
        "classless golden must carry no slo key"
    );
    if golden_out.is_some() {
        dump.push_str("two_tenant_untagged\n");
        dump.push_str(&untagged);
        dump.push('\n');
    }

    table.print();
    println!(
        "\ngolden replay across all cells: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    if let Some(path) = &golden_out {
        std::fs::write(path, dump).expect("write GOLDEN_OUT");
        println!("classless goldens: {path}");
    }
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
