//! `cocoserve` — the launcher CLI.
//!
//! ```text
//! cocoserve sim   [--policy coco|vllm|hft] [--model llama2-13b|llama2-70b]
//!                 [--rps N] [--duration S] [--instances N] [--devices N]
//!                 [--max-batch N] [--seed N] [--config file.json]
//! cocoserve serve [--rps N] [--duration S] [--max-batch N] [--seed N]
//!                 [--artifacts-dir DIR]       # real tiny model on CPU PJRT
//! cocoserve inspect [--artifacts-dir DIR]     # artifact/manifest summary
//! cocoserve trace [--scenario steady|diurnal|burst|ramp|two_tenant]
//!                 [--out trace.json] [...sim flags]
//!                                             # telemetry-on sim run that
//!                                             # exports a Perfetto trace
//! ```

use anyhow::{anyhow, Context, Result};

use cocoserve::cluster::Cluster;
use cocoserve::config::RunConfig;
use cocoserve::coordinator::{serve_trace, ServeConfig};
use cocoserve::engine::TinyEngine;
use cocoserve::placement::Placement;
use cocoserve::runtime::{default_artifacts_dir, Manifest};
use cocoserve::scheduler::SchedulerConfig;
use cocoserve::sim::{SimConfig, Simulation};
use cocoserve::workload::{Arrival, LengthDist, Trace};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_args(args: &[String]) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        let key = a
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got `{a}`"))?;
        if key == "config" {
            let path = it.next().ok_or_else(|| anyhow!("--config needs a path"))?;
            let base = RunConfig::load(path)?;
            let mode = cfg.mode.clone();
            cfg = base;
            cfg.mode = mode;
        } else {
            let v = it
                .next()
                .ok_or_else(|| anyhow!("--{key} needs a value"))?;
            cfg.set(key, v)?;
        }
    }
    Ok(cfg)
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("usage: cocoserve <sim|serve|inspect> [flags]  (see --help)");
        return Ok(());
    };
    match cmd.as_str() {
        "sim" => {
            let mut cfg = parse_args(&args[1..])?;
            cfg.mode = "sim".into();
            cmd_sim(&cfg)
        }
        "serve" => {
            let mut cfg = parse_args(&args[1..])?;
            cfg.mode = "serve".into();
            cmd_serve(&cfg)
        }
        "inspect" => cmd_inspect(&parse_args(&args[1..])?),
        "trace" => {
            let mut cfg = parse_args(&args[1..])?;
            cfg.mode = "trace".into();
            cmd_trace(&cfg)
        }
        "--help" | "-h" | "help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown command `{other}` (sim|serve|inspect|trace)")),
    }
}

const HELP: &str = "cocoserve — fine-grained LLM serving via dynamic module scaling

commands:
  sim      paper-scale discrete-event simulation (13B/70B over 4xA100 specs)
  serve    serve the real tiny model end-to-end on CPU PJRT
  inspect  summarize the AOT artifact directory
  trace    sim run with telemetry on; exports a Chrome/Perfetto trace JSON
           (open the file at https://ui.perfetto.dev)

common flags: --policy hft|vllm|coco|coco-noscale  --rps N  --duration S
              --max-batch N  --instances N  --devices N  --seed N
              --model llama2-13b|llama2-70b (sim)  --config file.json
              --artifacts-dir DIR (serve/inspect)
              --scenario steady|diurnal|burst|ramp|two_tenant (trace)
              --out trace.json (trace)";

fn sim_setup(cfg: &RunConfig) -> Result<(SimConfig, Cluster, Vec<(Placement, cocoserve::sim::SimPolicy)>)> {
    let sim_cfg = match cfg.model.as_str() {
        "llama2-13b" => SimConfig::paper_13b(),
        "llama2-70b" => SimConfig::paper_70b(),
        other => return Err(anyhow!("sim supports llama2-13b|llama2-70b, got {other}")),
    };
    let cluster = Cluster::homogeneous(
        cfg.devices,
        cocoserve::cluster::DeviceSpec::a100_40gb(),
    );
    let n_layers = sim_cfg.model.n_layers;
    let mut placements = Vec::new();
    for i in 0..cfg.instances {
        // instance i homed on device i (mod devices); 70B spans two devices
        let home = i % cfg.devices;
        let placement = if sim_cfg.model.d_model >= 8192 {
            let second = (home + 1) % cfg.devices;
            Placement::contiguous_shards(n_layers, &[home, second])
        } else {
            Placement::single_device(n_layers, home)
        };
        placements.push((placement, cfg.policy.sim_policy(cfg.max_batch)));
    }
    Ok((sim_cfg, cluster, placements))
}

fn cmd_sim(cfg: &RunConfig) -> Result<()> {
    let (sim_cfg, cluster, placements) = sim_setup(cfg)?;
    let sim = Simulation::new(sim_cfg, cluster, placements);
    let trace = Trace::generate(
        Arrival::Poisson { rps: cfg.rps },
        LengthDist::alpaca(),
        cfg.duration_s,
        cfg.seed,
    );
    println!(
        "sim: {} · {} · {} instance(s) on {} device(s) · {:.0} rps · {:.0}s · {} requests",
        cfg.policy.name(), cfg.model, cfg.instances, cfg.devices, cfg.rps,
        cfg.duration_s, trace.len()
    );
    let report = sim.run(&trace, cfg.duration_s);
    let mut lat = report.merged_latency();
    println!("completed        : {}", report.total_completed());
    println!("throughput       : {:.1} tok/s", report.total_throughput_tps());
    println!("latency mean/p95 : {:.2}s / {:.2}s", lat.mean(), lat.p95());
    println!("SLO attainment   : {:.1}%", report.slo_attainment() * 100.0);
    println!("OOM events       : {}", report.total_oom_events);
    println!(
        "scaling          : {} up / {} down ({:.2}s op time)",
        report.scale_ups, report.scale_downs, report.scale_op_time_s
    );
    for (d, util, mem) in &report.device_util {
        println!("device {d}         : util {:.0}% · mem {:.0}%", util * 100.0, mem * 100.0);
    }
    Ok(())
}

fn cmd_trace(cfg: &RunConfig) -> Result<()> {
    let (mut sim_cfg, cluster, placements) = sim_setup(cfg)?;
    sim_cfg.telemetry = Some(cocoserve::telemetry::TelemetryConfig::default());
    let sim = Simulation::new(sim_cfg, cluster, placements);
    let trace = match cfg.scenario.as_str() {
        "steady" => Trace::steady(cfg.rps, cfg.duration_s, cfg.seed),
        "diurnal" => Trace::diurnal(cfg.rps, cfg.duration_s, cfg.seed),
        "burst" => Trace::burst(cfg.rps, cfg.duration_s, cfg.seed),
        "ramp" => Trace::ramp(cfg.rps, cfg.duration_s, cfg.seed),
        "two_tenant" | "two-tenant" => Trace::two_tenant(cfg.rps, cfg.duration_s, cfg.seed),
        other => {
            return Err(anyhow!(
                "unknown scenario `{other}` (steady|diurnal|burst|ramp|two_tenant)"
            ))
        }
    };
    println!(
        "trace: {} · {} · scenario {} · {} instance(s) on {} device(s) · {:.0}s · {} requests",
        cfg.policy.name(), cfg.model, cfg.scenario, cfg.instances, cfg.devices,
        cfg.duration_s, trace.len()
    );
    let report = sim.run(&trace, cfg.duration_s);
    let out = cfg.out.as_deref().unwrap_or("trace.json");
    let chrome = report
        .chrome_trace()
        .ok_or_else(|| anyhow!("telemetry produced no trace buffer"))?;
    std::fs::write(out, chrome.to_string())
        .with_context(|| format!("writing {out}"))?;
    println!("completed        : {}", report.total_completed());
    if let Some(tl) = &report.timeline {
        println!(
            "timeline         : {} windows x {:.1}s",
            tl.windows.len(), tl.window_s
        );
    }
    if let Some(buf) = &report.trace {
        println!(
            "trace events     : {} recorded · {} dropped",
            buf.events.len(), buf.dropped
        );
    }
    println!("wrote {out} — open it at https://ui.perfetto.dev");
    Ok(())
}

fn cmd_serve(cfg: &RunConfig) -> Result<()> {
    let dir = cfg
        .artifacts_dir
        .as_ref()
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "no artifacts at {} — run `make artifacts`",
        dir.display()
    );
    let model = if cfg.model.starts_with("llama2") { "tiny-llama" } else { &cfg.model };
    let engine = TinyEngine::open(&dir, model).context("opening engine")?;
    let trace = Trace::generate(
        Arrival::Poisson { rps: cfg.rps },
        LengthDist::tiny(),
        cfg.duration_s,
        cfg.seed,
    );
    println!(
        "serve: {} ({} layers, d={}) · {:.0} rps · {:.0}s · {} requests · CPU PJRT",
        model, engine.cfg.n_layers, engine.cfg.d_model, cfg.rps, cfg.duration_s,
        trace.len()
    );
    let serve_cfg = ServeConfig {
        scheduler: SchedulerConfig::continuous(cfg.max_batch),
        slo_latency_s: 2.0,
        realtime: true,
    };
    let report = serve_trace(&engine, &trace, serve_cfg)?;
    let mut lat = report.monitor.latency_summary();
    println!("completed        : {}", report.completed);
    println!("generated tokens : {}", report.generated_tokens);
    println!("throughput       : {:.1} tok/s", report.tokens_per_s());
    println!("latency mean/p95 : {:.0}ms / {:.0}ms", lat.mean() * 1e3, lat.p95() * 1e3);
    println!("SLO attainment   : {:.1}%", report.monitor.slo_attainment() * 100.0);
    println!("PJRT executions  : {}", report.executions);
    Ok(())
}

fn cmd_inspect(cfg: &RunConfig) -> Result<()> {
    let dir = cfg
        .artifacts_dir
        .as_ref()
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let m = Manifest::load(&dir.join("manifest.json"))?;
    println!("artifacts root : {}", dir.display());
    println!("batch buckets  : {:?}", m.batch_buckets);
    println!("seq buckets    : {:?} (max_seq {})", m.seq_buckets, m.max_seq_len);
    for (name, c) in &m.configs {
        println!(
            "config {name}: d={} heads={} layers={} ff={} vocab={}",
            c.d_model, c.n_heads, c.n_layers, c.d_ff, c.vocab_size
        );
    }
    let mut by_module: std::collections::BTreeMap<&str, usize> = Default::default();
    for a in m.artifacts() {
        *by_module.entry(a.module.as_str()).or_insert(0) += 1;
    }
    println!("artifacts      :");
    for (module, n) in by_module {
        println!("  {module:<14} ×{n}");
    }
    Ok(())
}
