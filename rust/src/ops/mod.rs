//! Module-level operations: **replicate**, **migrate**, **evict** (§3.1).
//!
//! These are the paper's primitive operators. Each operation:
//!
//! 1. moves/duplicates the module's bytes between device ledgers (and, on
//!    the real path, the engine moves the weight literals / KV buffers),
//! 2. updates the [`Placement`],
//! 3. returns an [`OpCost`] from the transfer model below.
//!
//! ### Cost model (reproduces Table 2)
//!
//! The paper measures replication of *n* decoder layers of LLaMA-13B at
//! 0.2987 s (n=1) → 0.8938 s (n=40) with memory 1107 MB → 24819 MB, and
//! migration ≈ 45 ms cheaper (no new dataflow hooks to install). We model
//!
//! ```text
//! memory(n) = OVERHEAD + n · (layer_bytes + ACT_BUFFER)       (linear — exact)
//! time(n)   = LAUNCH + n · layer_bytes / (link_bw · (1 − mem_frac_dst))
//! ```
//!
//! The `(1 − mem_frac)` term models transfer slowdown as the target device
//! fills (pinned-buffer contention) — it reproduces the paper's superlinear
//! time growth at n→40 while staying principled (bytes / effective
//! bandwidth). Post-scaling inter-replica communication setup is the
//! paper's measured 39.1 ms constant.

use crate::cluster::Cluster;
use crate::model::cost::{CostModel, Shape, MIB};
use crate::model::{ModuleId, ModuleKind};
use crate::placement::Placement;

/// Fixed launch/bookkeeping latency of a replication (hook installation,
/// allocator setup). Calibrated to Table 2's n=1 row.
pub const REPLICATION_LAUNCH_S: f64 = 0.292;
/// Migration launches faster: the source's hooks are reused (§3.1).
pub const MIGRATION_LAUNCH_S: f64 = 0.242;
/// Fixed runtime overhead added once per operation batch (CUDA context,
/// staging buffers) — Table 2's memory intercept.
pub const OP_OVERHEAD_BYTES: f64 = 499.0 * MIB;
/// Per-layer activation/workspace buffer beyond the weights (Table 2's
/// 608 MiB/layer step vs the 605 MiB weight size).
pub const ACT_BUFFER_BYTES: f64 = 3.0 * MIB;
/// Post-scaling inter-replica communication setup (§6.5: 39.1 ms).
pub const REPLICA_COMM_SETUP_S: f64 = 0.0391;

/// Cost of one executed operation.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct OpCost {
    pub time_s: f64,
    pub bytes_moved: f64,
    /// Memory newly resident on the destination device.
    pub dst_bytes: f64,
}

impl OpCost {
    fn merge(self, other: OpCost) -> OpCost {
        OpCost {
            time_s: self.time_s + other.time_s,
            bytes_moved: self.bytes_moved + other.bytes_moved,
            dst_bytes: self.dst_bytes + other.dst_bytes,
        }
    }
}

#[derive(Debug)]
pub enum OpError {
    DestinationOom(crate::cluster::AllocError),
    AlreadyResident(usize, usize),
    NoSuchReplica(usize, usize),
}

impl std::fmt::Display for OpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OpError::DestinationOom(e) => write!(f, "destination OOM: {e}"),
            OpError::AlreadyResident(l, d) => {
                write!(f, "layer {l} already resident on device {d}")
            }
            OpError::NoSuchReplica(l, d) => {
                write!(f, "no replica of layer {l} on device {d}")
            }
        }
    }
}

impl std::error::Error for OpError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            OpError::DestinationOom(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::cluster::AllocError> for OpError {
    fn from(e: crate::cluster::AllocError) -> OpError {
        OpError::DestinationOom(e)
    }
}

/// Executes module operations against a cluster + placement, with costs
/// from the instance's [`CostModel`].
pub struct ModuleOps<'a> {
    pub cost_model: &'a CostModel,
    /// Precision of resident weights (2 = bf16 at paper scale, 4 = f32 tiny).
    pub dtype_bytes: usize,
    /// Tag prefix for ledger entries, e.g. "inst0".
    pub tag_prefix: String,
}

impl<'a> ModuleOps<'a> {
    pub fn new(cost_model: &'a CostModel, dtype_bytes: usize, tag_prefix: &str) -> Self {
        ModuleOps { cost_model, dtype_bytes, tag_prefix: tag_prefix.into() }
    }

    fn shape(&self) -> Shape {
        Shape { batch: 1, seq: 1, dtype_bytes: self.dtype_bytes }
    }

    /// Resident bytes of a module copy (weights + activation workspace).
    pub fn module_bytes(&self, kind: ModuleKind) -> f64 {
        self.cost_model.weight_bytes(kind, self.shape())
            + if kind == ModuleKind::DecoderLayer { ACT_BUFFER_BYTES } else { 0.0 }
    }

    /// Ledger tag for a module copy on a device.
    pub fn tag(&self, m: &ModuleId, device: usize) -> String {
        format!("{}/{}@{}", self.tag_prefix, m, device)
    }

    /// Deploy an instance's weights onto the placement's primary devices:
    /// one tagged allocation per decoder layer plus embed + lm_head on the
    /// first layer's device. Charges no time (deployment happens before
    /// serving); the per-module tags are what later migrations move.
    pub fn deploy_instance(
        &self,
        cluster: &mut Cluster,
        placement: &Placement,
    ) -> Result<f64, OpError> {
        let mut total = 0.0;
        for l in 0..placement.n_layers {
            let m = ModuleId::layer(ModuleKind::DecoderLayer, l);
            let d = placement.primary_device(l);
            let bytes = self.module_bytes(ModuleKind::DecoderLayer);
            cluster.device_mut(d).alloc(&self.tag(&m, d), bytes)?;
            total += bytes;
        }
        for kind in [ModuleKind::Embed, ModuleKind::LmHead] {
            let m = ModuleId::global(kind);
            let d = placement.primary_device(0);
            let bytes = self.module_bytes(kind);
            cluster.device_mut(d).alloc(&self.tag(&m, d), bytes)?;
            total += bytes;
        }
        Ok(total)
    }

    /// Transfer time for `bytes` into `dst`, with fill-contention slowdown.
    pub fn transfer_time(&self, cluster: &Cluster, src: usize, dst: usize, bytes: f64) -> f64 {
        let bw = cluster.link_bw(src, dst);
        let slow = (1.0 - cluster.device(dst).mem_frac()).max(0.25);
        bytes / (bw * slow)
    }

    // ---- replicate ---------------------------------------------------------

    /// Replicate decoder layer `layer` onto `dst` (§3.1 Fig. 4): allocate a
    /// copy of the layer's weights on `dst`, register the replica in the
    /// placement, charge transfer + hook-installation time.
    pub fn replicate_layer(
        &self,
        cluster: &mut Cluster,
        placement: &mut Placement,
        layer: usize,
        dst: usize,
    ) -> Result<OpCost, OpError> {
        if placement.layer_devices(layer).contains(&dst) {
            return Err(OpError::AlreadyResident(layer, dst));
        }
        let src = placement.primary_device(layer);
        let bytes = self.module_bytes(ModuleKind::DecoderLayer);
        let m = ModuleId::layer(ModuleKind::DecoderLayer, layer);
        let time = REPLICATION_LAUNCH_S / 1.0_f64.max(1.0)
            + self.transfer_time(cluster, src, dst, bytes);
        cluster
            .device_mut(dst)
            .alloc(&self.tag(&m, dst), bytes)?;
        placement.add_replica(layer, dst);
        Ok(OpCost { time_s: time, bytes_moved: bytes, dst_bytes: bytes })
    }

    /// Replicate a *batch* of layers in one operation — the Table 2 shape.
    /// The launch cost is paid once; transfers are sequential on the link.
    pub fn replicate_layers(
        &self,
        cluster: &mut Cluster,
        placement: &mut Placement,
        layers: &[usize],
        dst: usize,
    ) -> Result<OpCost, OpError> {
        let mut total = OpCost { time_s: REPLICATION_LAUNCH_S, ..Default::default() };
        for &l in layers {
            let src = placement.primary_device(l);
            let bytes = self.module_bytes(ModuleKind::DecoderLayer);
            let m = ModuleId::layer(ModuleKind::DecoderLayer, l);
            let t = self.transfer_time(cluster, src, dst, bytes);
            cluster.device_mut(dst).alloc(&self.tag(&m, dst), bytes)?;
            placement.add_replica(l, dst);
            total = total.merge(OpCost { time_s: t, bytes_moved: bytes, dst_bytes: bytes });
        }
        Ok(total)
    }

    // ---- migrate -----------------------------------------------------------

    /// Migrate a whole decoder layer: copy to `dst`, free on the source,
    /// repoint the placement primary (§3.1 Fig. 5; optionally the KV cache
    /// moves with it — the engine handles cache bytes separately).
    pub fn migrate_layer(
        &self,
        cluster: &mut Cluster,
        placement: &mut Placement,
        layer: usize,
        dst: usize,
    ) -> Result<OpCost, OpError> {
        let src = placement.primary_device(layer);
        if src == dst || placement.layer_devices(layer).contains(&dst) {
            return Err(OpError::AlreadyResident(layer, dst));
        }
        let bytes = self.module_bytes(ModuleKind::DecoderLayer);
        let m = ModuleId::layer(ModuleKind::DecoderLayer, layer);
        let time = MIGRATION_LAUNCH_S + self.transfer_time(cluster, src, dst, bytes);
        cluster.device_mut(dst).alloc(&self.tag(&m, dst), bytes)?;
        // Free the source copy only after the destination allocation
        // succeeded (migration must never lose the module).
        let _ = cluster.device_mut(src).free(&self.tag(&m, src));
        placement.migrate_layer(layer, dst);
        Ok(OpCost { time_s: time, bytes_moved: bytes, dst_bytes: bytes })
    }

    /// Migrate a batch of layers (Table 2's migration column).
    pub fn migrate_layers(
        &self,
        cluster: &mut Cluster,
        placement: &mut Placement,
        layers: &[usize],
        dst: usize,
    ) -> Result<OpCost, OpError> {
        let mut total = OpCost { time_s: MIGRATION_LAUNCH_S, ..Default::default() };
        for &l in layers {
            let src = placement.primary_device(l);
            if src == dst {
                continue;
            }
            let bytes = self.module_bytes(ModuleKind::DecoderLayer);
            let m = ModuleId::layer(ModuleKind::DecoderLayer, l);
            let t = self.transfer_time(cluster, src, dst, bytes);
            cluster.device_mut(dst).alloc(&self.tag(&m, dst), bytes)?;
            let _ = cluster.device_mut(src).free(&self.tag(&m, src));
            placement.migrate_layer(l, dst);
            total = total.merge(OpCost { time_s: t, bytes_moved: bytes, dst_bytes: bytes });
        }
        Ok(total)
    }

    /// Migrate a sub-layer module (projection, attention, FFN, or KV cache —
    /// §3.3 granularity). `extra_bytes` covers dynamic payloads (KV cache
    /// contents); weight-bearing kinds use the cost model's size.
    pub fn migrate_module(
        &self,
        cluster: &mut Cluster,
        placement: &mut Placement,
        m: ModuleId,
        dst: usize,
        extra_bytes: f64,
    ) -> Result<OpCost, OpError> {
        let src = placement.module_device(m);
        let bytes = self.module_bytes(m.kind) + extra_bytes;
        let time = MIGRATION_LAUNCH_S + self.transfer_time(cluster, src, dst, bytes);
        cluster.device_mut(dst).alloc(&self.tag(&m, dst), bytes)?;
        let _ = cluster.device_mut(src).free(&self.tag(&m, src));
        placement.migrate_module(m, dst);
        Ok(OpCost { time_s: time, bytes_moved: bytes, dst_bytes: bytes })
    }

    // ---- evict ------------------------------------------------------------

    /// Remove a layer replica (scale-down phase 2). Frees destination
    /// memory; near-instant (no transfer).
    pub fn evict_replica(
        &self,
        cluster: &mut Cluster,
        placement: &mut Placement,
        layer: usize,
        device: usize,
    ) -> Result<OpCost, OpError> {
        if !placement.remove_replica(layer, device) {
            return Err(OpError::NoSuchReplica(layer, device));
        }
        let m = ModuleId::layer(ModuleKind::DecoderLayer, layer);
        let freed = cluster.device_mut(device).free(&self.tag(&m, device)).unwrap_or(0.0);
        Ok(OpCost { time_s: 0.002, bytes_moved: 0.0, dst_bytes: -freed })
    }

    /// Table 2 analytic costs for an n-layer operation onto a device at
    /// `dst_mem_frac` fill — used by the bench and by planning (the
    /// controller consults this before executing).
    pub fn table2_cost(&self, n_layers: usize, link_bw: f64, dst_mem_frac: f64,
                       migration: bool) -> (f64, f64) {
        let layer_bytes = self.module_bytes(ModuleKind::DecoderLayer);
        let launch = if migration { MIGRATION_LAUNCH_S } else { REPLICATION_LAUNCH_S };
        let slow = (1.0 - dst_mem_frac).max(0.25);
        let time = launch + n_layers as f64 * layer_bytes / (link_bw * slow);
        let mem = OP_OVERHEAD_BYTES + n_layers as f64 * layer_bytes;
        (time, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::model::ModelConfig;

    fn setup() -> (CostModel, Cluster, Placement) {
        let cm = CostModel::new(ModelConfig::llama2_13b());
        let cluster = Cluster::paper_testbed();
        let placement = Placement::single_device(40, 0);
        (cm, cluster, placement)
    }

    #[test]
    fn replicate_allocates_and_registers() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let c = ops.replicate_layer(&mut cl, &mut pl, 5, 1).unwrap();
        assert!(pl.layer_devices(5).contains(&1));
        assert!(cl.device(1).used_bytes() > 600.0 * MIB);
        assert!(c.time_s > REPLICATION_LAUNCH_S);
        assert!(c.time_s < 1.0, "sub-second op: {}", c.time_s);
    }

    #[test]
    fn replicate_twice_rejected() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        ops.replicate_layer(&mut cl, &mut pl, 5, 1).unwrap();
        assert!(matches!(
            ops.replicate_layer(&mut cl, &mut pl, 5, 1),
            Err(OpError::AlreadyResident(5, 1))
        ));
    }

    #[test]
    fn migrate_moves_bytes_between_ledgers() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        // seed the source ledger with the layer's residency
        let m = ModuleId::layer(ModuleKind::DecoderLayer, 3);
        let bytes = ops.module_bytes(ModuleKind::DecoderLayer);
        cl.device_mut(0).alloc(&ops.tag(&m, 0), bytes).unwrap();

        let before_src = cl.device(0).used_bytes();
        ops.migrate_layer(&mut cl, &mut pl, 3, 2).unwrap();
        assert_eq!(pl.primary_device(3), 2);
        assert!(cl.device(0).used_bytes() < before_src);
        assert!((cl.device(2).used_bytes() - bytes).abs() < 1.0);
    }

    #[test]
    fn migration_cheaper_than_replication() {
        let (cm, cl, _) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let bw = cl.link_bw(0, 1);
        for n in [1, 10, 20, 40] {
            let (tr, _) = ops.table2_cost(n, bw, 0.1, false);
            let (tm, _) = ops.table2_cost(n, bw, 0.1, true);
            assert!(tm < tr, "n={n}: migration {tm} !< replication {tr}");
            assert!((tr - tm - 0.05).abs() < 0.01);
        }
    }

    /// Table 2's headline properties: sub-second ops, ~3× time for 40×
    /// layers, exactly-linear memory at 608 MiB/layer + 499 MiB overhead.
    #[test]
    fn table2_shape_reproduced() {
        let (cm, cl, _) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let bw = cl.link_bw(0, 1);
        let frac = |n: usize| (499.0 + 608.0 * n as f64) * MIB / cl.device(0).spec.mem_bytes;
        let (t1, m1) = ops.table2_cost(1, bw, frac(1), false);
        let (t40, m40) = ops.table2_cost(40, bw, frac(40), false);
        assert!((0.25..0.40).contains(&t1), "t1={t1}");
        assert!((0.60..1.30).contains(&t40), "t40={t40}");
        assert!(t40 / t1 < 5.0, "40x layers only ~3x time: {}", t40 / t1);
        assert!((m1 / MIB - 1107.0).abs() < 5.0, "m1={}", m1 / MIB);
        assert!((m40 / MIB - 24819.0).abs() < 50.0, "m40={}", m40 / MIB);
    }

    #[test]
    fn evict_frees_memory() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        ops.replicate_layer(&mut cl, &mut pl, 7, 1).unwrap();
        let used = cl.device(1).used_bytes();
        ops.evict_replica(&mut cl, &mut pl, 7, 1).unwrap();
        assert!(cl.device(1).used_bytes() < used);
        assert_eq!(pl.degree(7), 1);
        assert!(matches!(
            ops.evict_replica(&mut cl, &mut pl, 7, 1),
            Err(OpError::NoSuchReplica(7, 1))
        ));
    }

    #[test]
    fn kv_cache_migration_charges_payload() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let kv = ModuleId::layer(ModuleKind::KvCache, 0);
        let payload = 2.0e9; // 2 GB of cache
        let c = ops.migrate_module(&mut cl, &mut pl, kv, 3, payload).unwrap();
        assert!(c.bytes_moved >= payload);
        assert_eq!(pl.module_device(kv), 3);
        assert!(cl.device(3).used_bytes() >= payload);
    }

    #[test]
    fn oom_destination_rejected_without_state_change() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        cl.device_mut(1).alloc("hog", 39.9 * 1024.0 * MIB).unwrap();
        let r = ops.replicate_layer(&mut cl, &mut pl, 0, 1);
        assert!(matches!(r, Err(OpError::DestinationOom(_))));
        assert_eq!(pl.degree(0), 1);
    }

    #[test]
    fn replication_batch_amortizes_launch() {
        let (cm, mut cl, mut pl) = setup();
        let ops = ModuleOps::new(&cm, 2, "inst0");
        let batch = ops
            .replicate_layers(&mut cl, &mut pl, &[0, 1, 2, 3], 1)
            .unwrap();
        let mut cl2 = Cluster::paper_testbed();
        let mut pl2 = Placement::single_device(40, 0);
        let mut single = OpCost::default();
        for l in 0..4 {
            single = single.merge(
                ops.replicate_layer(&mut cl2, &mut pl2, l, 1).unwrap(),
            );
        }
        assert!(batch.time_s < single.time_s);
    }
}
