//! Module placement: which device hosts which module (and its replicas).
//!
//! The paper's scaling state is the vector `P = [p_1 … p_n]` of per-layer
//! parallelism degrees (§4.1) plus the device assignment behind each
//! replica. [`Placement`] is that state for one model instance:
//!
//! * every decoder layer has a **primary** device plus zero or more
//!   **replica** devices (`p_i = 1 + replicas`),
//! * sub-layer modules (attention, FFN, projections, KV cache) may be
//!   **migrated** away from the layer's primary device,
//! * `continuity` scores consecutive-layer co-location — Algorithm 1 sorts
//!   replication candidates by it to minimize scatter/all-gather boundaries
//!   (§3.2: "the continuity between replicas affects the communication
//!   overhead").

pub mod profile;

use std::collections::BTreeMap;

use crate::model::{ModuleId, ModuleKind};

pub use profile::PlacementProfile;

/// Placement of one model instance across the cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    /// Decoder-layer count of the placed model.
    pub n_layers: usize,
    /// Primary device of each layer.
    primary: Vec<usize>,
    /// Extra replica devices per layer (order = creation order).
    replicas: Vec<Vec<usize>>,
    /// Sub-layer modules migrated off their layer's primary device.
    migrated: BTreeMap<ModuleId, usize>,
}

impl Placement {
    /// All layers (and implicitly embed/lm_head) on a single device.
    pub fn single_device(n_layers: usize, device: usize) -> Placement {
        Placement {
            n_layers,
            primary: vec![device; n_layers],
            replicas: vec![Vec::new(); n_layers],
            migrated: BTreeMap::new(),
        }
    }

    /// Layers split contiguously across `devices` (pipeline-style shards).
    pub fn contiguous_shards(n_layers: usize, devices: &[usize]) -> Placement {
        assert!(!devices.is_empty());
        let per = n_layers.div_ceil(devices.len());
        let primary = (0..n_layers).map(|l| devices[(l / per).min(devices.len() - 1)]).collect();
        Placement {
            n_layers,
            primary,
            replicas: vec![Vec::new(); n_layers],
            migrated: BTreeMap::new(),
        }
    }

    // ---- the paper's P vector -------------------------------------------

    /// Parallelism degree p_i of a layer (1 = unreplicated).
    pub fn degree(&self, layer: usize) -> usize {
        1 + self.replicas[layer].len()
    }

    /// The state vector P = [p_1 … p_n] (§4.1).
    pub fn p_vector(&self) -> Vec<usize> {
        (0..self.n_layers).map(|l| self.degree(l)).collect()
    }

    /// ‖1 ⊘ P‖₁ = Σ 1/p_i — the Hadamard-quotient norm of Algorithm 1.
    pub fn inv_p_norm(&self) -> f64 {
        (0..self.n_layers).map(|l| 1.0 / self.degree(l) as f64).sum()
    }

    // ---- queries ----------------------------------------------------------

    /// Primary (original) device of a layer.
    pub fn primary_device(&self, layer: usize) -> usize {
        self.primary[layer]
    }

    /// All devices holding an executable copy of a layer (primary first).
    ///
    /// Allocates; hot paths use [`Placement::layer_device_iter`] /
    /// [`Placement::holds`] instead.
    pub fn layer_devices(&self, layer: usize) -> Vec<usize> {
        self.layer_device_iter(layer).collect()
    }

    /// Non-allocating view of a layer's devices, primary first, replicas in
    /// creation order — the same sequence [`Placement::layer_devices`]
    /// returns.
    pub fn layer_device_iter(&self, layer: usize) -> impl Iterator<Item = usize> + '_ {
        std::iter::once(self.primary[layer]).chain(self.replicas[layer].iter().copied())
    }

    /// Does `device` hold an executable copy (primary or replica) of
    /// `layer`? Non-allocating replacement for
    /// `layer_devices(layer).contains(&device)`.
    pub fn holds(&self, layer: usize, device: usize) -> bool {
        self.primary[layer] == device || self.replicas[layer].contains(&device)
    }

    /// Device a module actually executes on (honouring migrations).
    pub fn module_device(&self, m: ModuleId) -> usize {
        if let Some(&d) = self.migrated.get(&m) {
            return d;
        }
        match m.layer {
            Some(l) => self.primary[l],
            None => self.primary[0],
        }
    }

    /// Every sub-layer module migrated off its layer's primary device.
    pub fn migrations(&self) -> impl Iterator<Item = (&ModuleId, &usize)> {
        self.migrated.iter()
    }

    /// The migration override for a module, if any (`None` = the module
    /// lives with its layer's primary). Used by the plan executor to
    /// record the exact pre-op state for rollback.
    pub fn module_override(&self, m: ModuleId) -> Option<usize> {
        self.migrated.get(&m).copied()
    }

    /// Layers whose replica set contains `device`.
    pub fn replicas_on(&self, device: usize) -> Vec<usize> {
        (0..self.n_layers)
            .filter(|&l| self.replicas[l].contains(&device))
            .collect()
    }

    /// Layers with primary residence on `device`.
    pub fn primaries_on(&self, device: usize) -> Vec<usize> {
        (0..self.n_layers).filter(|&l| self.primary[l] == device).collect()
    }

    // ---- mutations (called by ops::replicate / ops::migrate) --------------

    /// Add a replica of `layer` on `device`. Idempotence is rejected: a
    /// device holds at most one copy of a layer.
    pub fn add_replica(&mut self, layer: usize, device: usize) {
        assert!(
            !self.holds(layer, device),
            "device {device} already holds layer {layer}"
        );
        self.replicas[layer].push(device);
    }

    /// Remove the replica of `layer` on `device` (not the primary).
    pub fn remove_replica(&mut self, layer: usize, device: usize) -> bool {
        if let Some(i) = self.replicas[layer].iter().position(|&d| d == device) {
            self.replicas[layer].remove(i);
            true
        } else {
            false
        }
    }

    /// Move a layer's primary residence (whole-layer migration).
    pub fn migrate_layer(&mut self, layer: usize, to: usize) {
        assert!(
            !self.replicas[layer].contains(&to),
            "target already holds a replica of layer {layer}"
        );
        self.primary[layer] = to;
    }

    /// Migrate a sub-layer module off its layer's primary device.
    pub fn migrate_module(&mut self, m: ModuleId, to: usize) {
        assert!(m.kind != ModuleKind::DecoderLayer,
                "whole layers use migrate_layer");
        self.migrated.insert(m, to);
    }

    /// Return a migrated module home (drops the override).
    pub fn unmigrate_module(&mut self, m: ModuleId) -> bool {
        self.migrated.remove(&m).is_some()
    }

    // ---- continuity (§3.2 / Algorithm 1) -----------------------------------

    /// Number of device transitions walking layers 0..n — each transition
    /// is a scatter/all-gather boundary. Lower = better. Allocation-free:
    /// compares the device sequences element-wise.
    pub fn transition_count(&self) -> usize {
        (1..self.n_layers)
            .filter(|&l| !self.layer_device_iter(l - 1).eq(self.layer_device_iter(l)))
            .count()
    }

    /// Length of the longest run of consecutive layers replicated on
    /// `device` if `candidate` were added — Algorithm 1's
    /// `SortCandidatesByContinuity` key. Allocation-free: walks outward
    /// from the candidate instead of materializing a held-layers table.
    pub fn continuity_with(&self, device: usize, candidate: usize) -> usize {
        let held = |l: usize| l == candidate || self.holds(l, device);
        // longest held-run containing `candidate`
        let mut lo = candidate;
        while lo > 0 && held(lo - 1) {
            lo -= 1;
        }
        let mut hi = candidate;
        while hi + 1 < self.n_layers && held(hi + 1) {
            hi += 1;
        }
        hi - lo + 1
    }

    /// Validity invariant (checked by property tests and debug assertions):
    /// no duplicate devices per layer, every index in range.
    pub fn validate(&self, n_devices: usize) -> Result<(), String> {
        if self.primary.len() != self.n_layers || self.replicas.len() != self.n_layers {
            return Err("layer arity mismatch".into());
        }
        for l in 0..self.n_layers {
            let devs = self.layer_devices(l);
            for &d in &devs {
                if d >= n_devices {
                    return Err(format!("layer {l} on unknown device {d}"));
                }
            }
            let mut sorted = devs.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != devs.len() {
                return Err(format!("layer {l} has duplicate devices"));
            }
        }
        for (m, &d) in &self.migrated {
            if d >= n_devices {
                return Err(format!("module {m} on unknown device {d}"));
            }
            if let Some(l) = m.layer {
                if l >= self.n_layers {
                    return Err(format!("module {m} beyond layer count"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn single_device_degrees() {
        let p = Placement::single_device(40, 0);
        assert_eq!(p.p_vector(), vec![1; 40]);
        assert_eq!(p.inv_p_norm(), 40.0);
        assert_eq!(p.transition_count(), 0);
    }

    #[test]
    fn contiguous_shards_split_evenly() {
        let p = Placement::contiguous_shards(40, &[0, 1]);
        assert_eq!(p.primaries_on(0).len(), 20);
        assert_eq!(p.primaries_on(1).len(), 20);
        assert_eq!(p.transition_count(), 1);
    }

    #[test]
    fn replica_changes_degree_and_inv_norm() {
        let mut p = Placement::single_device(4, 0);
        p.add_replica(2, 1);
        assert_eq!(p.p_vector(), vec![1, 1, 2, 1]);
        assert!((p.inv_p_norm() - 3.5).abs() < 1e-12);
        assert!(p.remove_replica(2, 1));
        assert!(!p.remove_replica(2, 1));
        assert_eq!(p.inv_p_norm(), 4.0);
    }

    #[test]
    #[should_panic(expected = "already holds")]
    fn duplicate_replica_rejected() {
        let mut p = Placement::single_device(4, 0);
        p.add_replica(1, 0); // device 0 is the primary
    }

    #[test]
    fn migration_overrides_module_device() {
        let mut p = Placement::single_device(4, 0);
        let kv = ModuleId::layer(ModuleKind::KvCache, 1);
        assert_eq!(p.module_device(kv), 0);
        p.migrate_module(kv, 2);
        assert_eq!(p.module_device(kv), 2);
        assert!(p.unmigrate_module(kv));
        assert_eq!(p.module_device(kv), 0);
    }

    #[test]
    fn layer_migration_moves_primary() {
        let mut p = Placement::single_device(4, 0);
        p.migrate_layer(3, 1);
        assert_eq!(p.primary_device(3), 1);
        assert_eq!(p.transition_count(), 1);
    }

    #[test]
    fn continuity_prefers_adjacent_layers() {
        let mut p = Placement::single_device(10, 0);
        p.add_replica(4, 1);
        p.add_replica(5, 1);
        // candidate 6 extends the run [4,5] -> continuity 3
        assert_eq!(p.continuity_with(1, 6), 3);
        // candidate 8 starts a fresh run -> continuity 1
        assert_eq!(p.continuity_with(1, 8), 1);
        // candidate 3 extends backwards -> 3
        assert_eq!(p.continuity_with(1, 3), 3);
    }

    #[test]
    fn transitions_counted_over_replica_sets() {
        let mut p = Placement::single_device(6, 0);
        assert_eq!(p.transition_count(), 0);
        p.add_replica(2, 1);
        p.add_replica(3, 1);
        // boundaries: 1->2 and 3->4
        assert_eq!(p.transition_count(), 2);
        p.add_replica(4, 1);
        assert_eq!(p.transition_count(), 2); // 1->2, 4->5
    }

    #[test]
    fn iter_accessors_match_vec_accessors() {
        let mut p = Placement::single_device(8, 0);
        p.add_replica(2, 1);
        p.add_replica(2, 3);
        p.add_replica(5, 2);
        for l in 0..8 {
            let v = p.layer_devices(l);
            let i: Vec<usize> = p.layer_device_iter(l).collect();
            assert_eq!(v, i, "layer {l}");
            for d in 0..4 {
                assert_eq!(p.holds(l, d), v.contains(&d), "layer {l} device {d}");
            }
        }
    }

    #[test]
    fn prop_random_ops_keep_placement_valid() {
        prop::check(
            "placement-valid",
            |r: &mut Rng| {
                (0..40)
                    .map(|_| (r.below(4) as u8, r.below(8) as usize, r.below(4) as usize))
                    .collect::<Vec<_>>()
            },
            |ops| {
                let mut p = Placement::single_device(8, 0);
                for &(op, layer, dev) in ops {
                    match op {
                        0 if !p.layer_devices(layer).contains(&dev) => {
                            p.add_replica(layer, dev);
                        }
                        1 => {
                            p.remove_replica(layer, dev);
                        }
                        2 if !p.replicas_on(dev).contains(&layer) => {
                            if !p.layer_devices(layer).contains(&dev)
                                || p.primary_device(layer) == dev
                            {
                                if !p.replicas_on(dev).contains(&layer)
                                    && !p.layer_devices(layer)[1..].contains(&dev)
                                {
                                    p.migrate_layer(layer, dev);
                                }
                            }
                        }
                        _ => {
                            p.migrate_module(
                                ModuleId::layer(ModuleKind::KvCache, layer),
                                dev,
                            );
                        }
                    }
                    p.validate(4).map_err(|e| format!("after {op}: {e}"))?;
                }
                Ok(())
            },
        );
    }
}
