//! Fleet control-plane contracts, tested through the public simulation API.
//!
//! * **Fleet golden replay** — the full fleet configuration (routing
//!   policies, admission backpressure, shed re-routing, instance
//!   spin-up/drain) must be byte-identically replayable per scenario and
//!   per routing policy, exactly like the fixed-fleet kernel.
//! * **Routing invariants** — every trace arrival is routed exactly once
//!   (the `routes` counter equals the trace length no matter how much
//!   backpressure parking happened), and conservation holds across
//!   OOM-shed re-routes: no request ever completes twice.
//! * **Lifecycle** — under burst pressure an elastic fleet spins new
//!   instances up, and the device-seconds bill stays strictly below the
//!   every-device-always-on ceiling.

use std::collections::BTreeSet;

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::coordinator::{FleetConfig, FleetPhase, RoutePolicy, RouterConfig};
use cocoserve::model::cost::CostModel;
use cocoserve::model::{ModelConfig, ModuleKind};
use cocoserve::ops::ModuleOps;
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimPolicy, SimReport, Simulation};
use cocoserve::workload::{FailureSchedule, Request, Trace};

fn run_fleet(
    n_seed: usize,
    n_devices: usize,
    policy: SimPolicy,
    setup: FleetSetup,
    trace: &Trace,
    duration_s: f64,
) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(n_devices, DeviceSpec::a100_40gb());
    let placements: Vec<_> = (0..n_seed)
        .map(|i| {
            (
                Placement::single_device(cfg.model.n_layers, i % n_devices),
                policy,
            )
        })
        .collect();
    Simulation::with_fleet(cfg, cluster, placements, setup).run(trace, duration_s)
}

fn elastic_setup(route: RoutePolicy, policy: SimPolicy) -> FleetSetup {
    FleetSetup {
        router: RouterConfig {
            policy: route,
            admission_limit: Some(64),
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(FleetConfig::elastic(2, 5, policy)),
        ..Default::default()
    }
}

/// Unique completed request ids across every monitor; panics on a
/// duplicate (a request that completed twice would break conservation).
fn completed_ids(r: &SimReport) -> BTreeSet<u64> {
    let mut seen = BTreeSet::new();
    for m in &r.monitors {
        for c in m.completions() {
            assert!(
                seen.insert(c.request_id),
                "request {} completed more than once",
                c.request_id
            );
        }
    }
    seen
}

#[test]
fn fleet_golden_replay_across_scenarios() {
    for (name, trace) in Trace::scenario_sweep(18.0, 12.0, 91) {
        let setup = elastic_setup(RoutePolicy::KvHeadroom, baselines::cocoserve(32));
        let a = run_fleet(2, 5, baselines::cocoserve(32), setup, &trace, 12.0);
        let b = run_fleet(2, 5, baselines::cocoserve(32), setup, &trace, 12.0);
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "fleet scenario `{name}` not replay-deterministic"
        );
        assert!(a.total_completed() > 0, "fleet scenario `{name}` served nothing");
    }
}

#[test]
fn fleet_golden_replay_holds_for_every_route_policy() {
    let trace = Trace::burst(20.0, 12.0, 17);
    for policy in [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstanding,
        RoutePolicy::KvHeadroom,
    ] {
        let setup = elastic_setup(policy, baselines::cocoserve(32));
        let a = run_fleet(2, 5, baselines::cocoserve(32), setup, &trace, 12.0)
            .to_json()
            .to_string();
        let b = run_fleet(2, 5, baselines::cocoserve(32), setup, &trace, 12.0)
            .to_json()
            .to_string();
        assert_eq!(a, b, "route policy {policy:?} not replay-deterministic");
    }
}

#[test]
fn every_arrival_is_routed_exactly_once() {
    // A tight admission limit forces the router to park requests; parked
    // requests are first-time routes when they finally deliver, so the
    // counter still comes out to exactly one route per arrival — and at
    // light load everything drains.
    let trace = Trace::steady(10.0, 12.0, 33);
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: Some(4),
            reroute_on_shed: false,
            ..RouterConfig::default()
        },
        ..Default::default()
    };
    let r = run_fleet(2, 2, baselines::vllm_like(16), setup, &trace, 12.0);
    assert_eq!(r.routes, trace.len() as u64, "each arrival routed exactly once");
    assert_eq!(r.reroutes, 0);
    let ids = completed_ids(&r);
    assert_eq!(ids.len(), trace.len(), "light load must fully drain");
    assert_eq!(r.total_completed(), trace.len());
}

#[test]
fn oom_shed_requests_reroute_without_double_completion() {
    // Memory-tight HFT fleet: FailBatch OOM handling sheds whole batches;
    // in fleet mode those requests go back through the router. Every
    // arrival is still routed exactly once as a first-time route, the
    // shed deliveries show up as reroutes, and no request completes on
    // two instances.
    let cfg = SimConfig::paper_13b();
    let mut cluster = Cluster::homogeneous(2, DeviceSpec::a100_40gb());
    for d in 0..2 {
        cluster.device_mut(d).alloc("co-tenant", 12.0 * GIB).unwrap();
    }
    let policy = baselines::hft(16);
    let placements: Vec<_> = (0..2)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
        .collect();
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: None,
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        ..Default::default()
    };
    let trace = Trace::burst(30.0, 15.0, 29);
    let r = Simulation::with_fleet(cfg, cluster, placements, setup).run(&trace, 15.0);
    assert_eq!(r.routes, trace.len() as u64, "first-time routes == arrivals");
    assert!(r.reroutes > 0, "memory-tight HFT fleet must shed and re-route");
    let ids = completed_ids(&r); // panics on any double completion
    assert!(ids.len() <= trace.len());
    assert!(
        r.total_completed() >= trace.len() * 8 / 10,
        "re-routing must keep most requests alive: {}/{}",
        r.total_completed(),
        trace.len()
    );
}

#[test]
fn burst_pressure_spins_instances_up_and_bills_less_than_static() {
    // Elastic fleet with module replication disabled (replica_budget 0):
    // the arbitration's only capacity option is whole-instance spin-up,
    // so burst pressure must produce SpinUp fleet events. The
    // device-seconds bill stays strictly below the every-device-always-on
    // ceiling that a static over-provisioned deployment would pay.
    let mut cfg = SimConfig::paper_13b();
    cfg.replica_budget = 0;
    let n_devices = 6;
    let cluster = Cluster::homogeneous(n_devices, DeviceSpec::a100_40gb());
    let policy = baselines::cocoserve_no_autoscale(32);
    let placements: Vec<_> = (0..2)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
        .collect();
    let mut fleet = FleetConfig::elastic(2, 6, policy);
    fleet.cooldown_ticks = 1;
    fleet.scale_out_queue = 12.0;
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: None,
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(fleet),
        ..Default::default()
    };
    let trace = Trace::burst(30.0, 30.0, 57);
    let r = Simulation::with_fleet(cfg, cluster, placements, setup).run(&trace, 30.0);
    assert!(
        r.fleet_events.iter().any(|e| e.phase == FleetPhase::SpinUp),
        "burst pressure must spin up at least one instance: {:?}",
        r.fleet_events
    );
    let ceiling = n_devices as f64 * r.duration_s;
    assert!(
        r.device_seconds < ceiling,
        "elastic bill {} must undercut the static ceiling {}",
        r.device_seconds,
        ceiling
    );
    assert!(r.device_seconds > 0.0);
}

#[test]
fn a_single_request_trace_completes() {
    // Regression: delivery happens via a same-timestamp Routed event, so
    // the kernel must count routed-but-undelivered requests as live —
    // otherwise the run loop breaks before the lone arrival lands.
    let trace = Trace {
        requests: vec![Request {
            id: 0,
            arrival_s: 0.5,
            prompt_tokens: 16,
            output_tokens: 4,
            class: Default::default(),
        }],
    };
    let r = run_fleet(2, 2, baselines::vllm_like(16), FleetSetup::default(), &trace, 5.0);
    assert_eq!(r.total_completed(), 1, "the lone arrival must be delivered and served");
    assert_eq!(r.routes, 1);
}

/// `n` arrivals spread over the first `window_s` seconds, then silence —
/// the shape that makes an elastic fleet scale in during the tail.
fn burst_then_silence(n: usize, window_s: f64, output_tokens: usize) -> Trace {
    Trace {
        requests: (0..n)
            .map(|i| Request {
                id: i as u64,
                arrival_s: window_s * (i as f64 + 0.5) / n as f64,
                prompt_tokens: 64,
                output_tokens,
                class: Default::default(),
            })
            .collect(),
    }
}

#[test]
fn preemption_mid_drain_sheds_cleanly_and_stops_billing() {
    // Probe/strike: run once without failures to learn exactly when the
    // elastic fleet drains an instance, then rerun with the device under
    // that instance preempted strictly inside its drain window. The
    // event prefix before the death is identical across the two runs, so
    // the victim is guaranteed to be `Draining` at the failure instant.
    // The regression contract: a drainer that dies before its clean
    // Release still flushes its live work back through the router, never
    // reaches the Release protocol, and bills nothing past the death.
    let policy = baselines::vllm_like(16);
    let trace = burst_then_silence(24, 4.0, 48);
    let duration = 40.0;
    let make = || {
        let cfg = SimConfig::paper_13b();
        let cluster = Cluster::mixed(vec![
            DeviceSpec::a100_40gb(),
            DeviceSpec::a100_40gb().spot(),
        ]);
        let placements: Vec<_> = (0..2)
            .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
            .collect();
        let setup = FleetSetup {
            router: RouterConfig {
                policy: RoutePolicy::LeastOutstanding,
                admission_limit: None,
                reroute_on_shed: true,
                ..RouterConfig::default()
            },
            fleet: Some(FleetConfig::elastic(1, 2, policy)),
            ..Default::default()
        };
        Simulation::with_fleet(cfg, cluster, placements, setup)
    };

    // probe: where is the drain window?
    let probe = make().run(&trace, duration);
    let drain = probe
        .fleet_events
        .iter()
        .find(|e| e.phase == FleetPhase::Drain)
        .expect("the silent tail must drain one instance")
        .clone();
    let victim = drain.instance;
    let release_t = probe
        .fleet_events
        .iter()
        .find(|e| e.instance == victim && e.phase == FleetPhase::Release)
        .expect("the drained instance must release cleanly in the probe run")
        .t;
    let t_fail = drain.t + 0.5;
    assert!(release_t > t_fail, "strike must land inside the drain window");
    // does the victim still hold live work at the strike instant?
    let in_flight = probe.monitors[victim]
        .completions()
        .iter()
        .filter(|c| c.finish_s > t_fail)
        .count();

    // strike: seed instances sit on their own device ids, so device
    // `victim` is the one under the draining instance
    let schedule = FailureSchedule::at(&[(t_fail, victim)]);
    let r = make().with_failures(schedule.clone()).run(&trace, duration);
    let again = make().with_failures(schedule).run(&trace, duration);
    assert_eq!(
        r.to_json().to_string(),
        again.to_json().to_string(),
        "mid-drain preemption must replay byte-identically"
    );

    // conservation: the survivor absorbs everything the drainer held
    let ids = completed_ids(&r);
    assert_eq!(ids.len(), trace.len(), "no request may be lost mid-drain");
    assert_eq!(r.total_completed(), trace.len());
    let audit = r.audit.as_ref().expect("failure runs carry an audit block");
    assert_eq!(audit.unrouted_at_end, 0);
    let kinds: Vec<&str> =
        audit.log.records().iter().map(|rec| rec.kind.name()).collect();
    assert!(kinds.contains(&"device_failed"), "audit: {kinds:?}");
    // 40 sole-copy layers cannot fit the survivor's ≤ 13.5 GB of slack,
    // so the dying drainer is deterministically force-released
    assert!(kinds.contains(&"forced_release"), "audit: {kinds:?}");
    assert!(kinds.contains(&"instance_lost"), "audit: {kinds:?}");
    if in_flight > 0 {
        assert!(r.reroutes > 0, "the drainer's live work must re-route");
        assert!(kinds.contains(&"requests_shed"), "audit: {kinds:?}");
    }
    // the victim never reaches the clean Release protocol…
    assert!(
        !r.fleet_events
            .iter()
            .any(|e| e.instance == victim && e.phase == FleetPhase::Release),
        "a dead drainer must not also release cleanly"
    );
    // …and its device bills nothing past the preemption instant
    assert!(
        r.device_seconds <= r.duration_s + t_fail + 1e-6,
        "dead device billed past preemption: {} vs {} + {t_fail}",
        r.device_seconds,
        r.duration_s
    );
}

#[test]
fn dead_drainer_releases_every_tag_on_surviving_devices() {
    // Probe/strike again, but each instance keeps its top 5 layers on a
    // brim-full side device (inst0 → d3, inst1 → d2), so the drainer
    // holds ledger tags on a device that survives the strike. After the
    // forced release the side device must hold exactly the hog bytes
    // again — proof that no `inst{id}/` allocation leaked. Emergency
    // migration is deliberately impossible (35 sole-copy layers ≈ 21 GB
    // against ≤ 13.5 GB of slack anywhere), so the outcome is
    // deterministically Lost whichever instance drains.
    let cfg = SimConfig::paper_13b();
    let n_layers = cfg.model.n_layers;
    let cm = CostModel::new(ModelConfig::llama2_13b());
    let probe_ops = ModuleOps::new(&cm, cfg.dtype_bytes, "probe");
    let layer_bytes = probe_ops.module_bytes(ModuleKind::DecoderLayer);
    let spec_bytes = DeviceSpec::a100_40gb().mem_bytes;
    // side devices keep 5 layers + half a layer of slack
    let hog = spec_bytes - 5.5 * layer_bytes;
    let upper_of = |v: usize| 3 - v;

    let policy = baselines::vllm_like(16);
    let trace = burst_then_silence(24, 4.0, 48);
    let duration = 40.0;
    let make = || {
        let mut cluster = Cluster::mixed(vec![
            DeviceSpec::a100_40gb(),
            DeviceSpec::a100_40gb().spot(),
            DeviceSpec::a100_40gb(),
            DeviceSpec::a100_40gb(),
        ]);
        for d in [2, 3] {
            cluster.device_mut(d).alloc("hog", hog).unwrap();
        }
        let placements: Vec<_> = (0..2)
            .map(|i| {
                let mut pl = Placement::single_device(n_layers, i);
                for l in (n_layers - 5)..n_layers {
                    pl.migrate_layer(l, upper_of(i));
                }
                (pl, policy)
            })
            .collect();
        let setup = FleetSetup {
            router: RouterConfig {
                policy: RoutePolicy::LeastOutstanding,
                admission_limit: None,
                reroute_on_shed: true,
                ..RouterConfig::default()
            },
            fleet: Some(FleetConfig::elastic(1, 4, policy)),
            ..Default::default()
        };
        Simulation::with_fleet(SimConfig::paper_13b(), cluster, placements, setup)
    };

    let probe = make().run(&trace, duration);
    let drain = probe
        .fleet_events
        .iter()
        .find(|e| e.phase == FleetPhase::Drain)
        .expect("the silent tail must drain one instance")
        .clone();
    let victim = drain.instance;
    let t_fail = drain.t + 0.5;
    let release_t = probe
        .fleet_events
        .iter()
        .find(|e| e.instance == victim && e.phase == FleetPhase::Release)
        .expect("the drained instance must release cleanly in the probe run")
        .t;
    assert!(release_t > t_fail, "strike must land inside the drain window");

    let r = make()
        .with_failures(FailureSchedule::at(&[(t_fail, victim)]))
        .run(&trace, duration);

    let ids = completed_ids(&r);
    assert_eq!(ids.len(), trace.len(), "no request may be lost mid-drain");
    let audit = r.audit.as_ref().expect("failure runs carry an audit block");
    assert_eq!(audit.unrouted_at_end, 0);
    let kinds: Vec<&str> =
        audit.log.records().iter().map(|rec| rec.kind.name()).collect();
    assert!(kinds.contains(&"forced_release"), "audit: {kinds:?}");
    assert!(kinds.contains(&"instance_lost"), "audit: {kinds:?}");

    // tag hygiene on the surviving side device: exactly the hog remains
    let (_, _, side_frac) = r.device_util[upper_of(victim)];
    assert!(
        (side_frac - hog / spec_bytes).abs() < 1e-12,
        "inst{victim}/ tags leaked on surviving device {}: frac {side_frac} vs hog {}",
        upper_of(victim),
        hog / spec_bytes
    );
    // the dead primary reads as full (failed-device marker)
    let (_, _, dead_frac) = r.device_util[victim];
    assert_eq!(dead_frac, 1.0);
    // both of the victim's devices stop billing at the death; the
    // survivor's two keep billing to the end of the run
    assert!(
        r.device_seconds <= 2.0 * r.duration_s + 2.0 * t_fail + 1e-6,
        "victim devices billed past the death: {} vs 2·{} + 2·{t_fail}",
        r.device_seconds,
        r.duration_s
    );
}

#[test]
fn default_setup_reproduces_the_fixed_fleet_kernel() {
    // Simulation::new must behave exactly like with_fleet + defaults —
    // the legacy least-outstanding routing with no lifecycle management.
    let trace = Trace::steady(15.0, 10.0, 3);
    let cfg = SimConfig::paper_13b();
    let make_placements = |cfg: &SimConfig| {
        (0..2)
            .map(|i| {
                (
                    Placement::single_device(cfg.model.n_layers, i),
                    baselines::vllm_like(16),
                )
            })
            .collect::<Vec<_>>()
    };
    let a = Simulation::new(
        cfg.clone(),
        Cluster::homogeneous(2, DeviceSpec::a100_40gb()),
        make_placements(&cfg),
    )
    .run(&trace, 10.0);
    let b = Simulation::with_fleet(
        cfg.clone(),
        Cluster::homogeneous(2, DeviceSpec::a100_40gb()),
        make_placements(&cfg),
        FleetSetup::default(),
    )
    .run(&trace, 10.0);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert!(a.fleet_events.is_empty(), "no lifecycle events without a fleet config");
    assert_eq!(a.reroutes, 0);
}
