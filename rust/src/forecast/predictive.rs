//! The predictive controller: forecast-driven capacity proposals, with
//! the reactive fleet controller as arbiter.
//!
//! ### Division of labor
//!
//! The reactive [`crate::coordinator::FleetController`] reads *live*
//! pressure (mean outstanding requests) and acts after demand has
//! arrived; this controller reads the [`TrafficForecaster`] and proposes
//! capacity *before* it arrives. The two are arbitrated by the kernel
//! under a documented precedence (DESIGN.md "Predictive control plane"):
//!
//! 1. **Reactive escalation always wins.** A live `ScaleOut` signal means
//!    demand is already here — it is enacted unconditionally.
//! 2. **Predictive proposals fill the Hold band**, subject to a reactive
//!    veto ([`PredictiveController::reactive_veto`]): when the live
//!    signal is deeply idle, the forecasted deficit is weak, and no burst
//!    is flagged, the live evidence outvotes the forecast.
//! 3. **Reactive scale-in is forecast-gated**
//!    ([`PredictiveController::block_drain`]): an instance is not drained
//!    if the forecast says its capacity is needed again within the drain
//!    horizon (cold start + margin — what re-acquiring it would cost).
//!
//! ### Lead-time selection
//!
//! Each action's forecast horizon is its own enactment latency, priced
//! exactly as the kernel enacts it: a replication plan's horizon is its
//! dry-run [`crate::plan::PlanCost`] duration (the op events are
//! scheduled with those exact spans), a spin-up's horizon is
//! `cold_start_s` (activation is gated on exactly that). Replication —
//! short horizon — bridges imminent deficits; spin-up — long horizon —
//! covers sustained ones; a tick may enact both when a burst needs the
//! bridge *and* the instance (see `Simulation::predictive_tick`).

use super::capacity::CapacityModel;
use super::estimator::{BurstDetector, Ewma, Holt, HoltWinters, TrafficForecaster};

/// Configuration of the predictive control plane. `Copy` so
/// [`crate::sim::FleetSetup`] stays `Copy`; everything sized here is
/// allocated once at controller construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictConfig {
    /// Rate-bucket width (seconds) of the streaming estimators.
    pub bucket_s: f64,
    /// Target instance utilization the capacity conversion plans to
    /// (the margin absorbing contention and length tails).
    pub target_util: f64,
    /// Mean prompt length of the planning-reference request (tokens).
    pub mean_prompt: usize,
    /// Mean output length of the planning-reference request (tokens).
    pub mean_output: usize,
    /// Reference batch size for the μ derivation.
    pub batch: usize,
    /// EWMA smoothing factor.
    pub ewma_alpha: f64,
    /// Holt level smoothing factor.
    pub holt_alpha: f64,
    /// Holt trend smoothing factor.
    pub holt_beta: f64,
    /// Holt-Winters seasonal smoothing factor.
    pub hw_gamma: f64,
    /// Holt-Winters seasonal period in buckets (1 degenerates to Holt).
    pub season_buckets: usize,
    /// Burst detector long-run smoothing factor (small = long memory).
    pub burst_alpha: f64,
    /// Burst detector firing threshold (standard deviations).
    pub burst_sigma: f64,
    /// Deficit (instance-equivalents) at the spin-up horizon from which
    /// a whole-instance spin-up is warranted.
    pub spin_deficit_eq: f64,
    /// Premium-first floor: under a class-aware routing policy a
    /// latency-sensitive deficit this deep (instance-equivalents, judged
    /// against the premium capacity claim —
    /// [`PREMIUM_CAPACITY_FRACTION`]) spins an instance even when the
    /// mixed-traffic deficit sits below `spin_deficit_eq`. Unused in
    /// classless runs.
    pub premium_spin_deficit_eq: f64,
    /// Deficit below which a deeply-idle live signal vetoes the proposal.
    pub veto_deficit_eq: f64,
    /// Margin added to `cold_start_s` for the drain-gating horizon.
    pub drain_margin_s: f64,
    /// Oracle mode: forecasts read the trace's true future rates
    /// (upper-bound benching; the kernel installs the rate table).
    pub oracle: bool,
}

impl Default for PredictConfig {
    fn default() -> Self {
        PredictConfig {
            bucket_s: 1.0,
            target_util: 0.6,
            mean_prompt: 96,
            mean_output: 64,
            batch: 16,
            ewma_alpha: 0.3,
            holt_alpha: 0.4,
            holt_beta: 0.2,
            hw_gamma: 0.3,
            season_buckets: 60,
            burst_alpha: 0.05,
            burst_sigma: 3.0,
            spin_deficit_eq: 0.9,
            premium_spin_deficit_eq: 0.45,
            veto_deficit_eq: 0.5,
            drain_margin_s: 2.0,
            oracle: false,
        }
    }
}

/// Share of live capacity the latency-sensitive class can claim without
/// waiting for a best-effort batch to be preempted: batch slots already
/// occupied by best-effort work free only at token boundaries, so the
/// premium planner counts on roughly half the fleet being immediately
/// claimable. Premium-first deficits
/// ([`PredictiveController::premium_deficit_at`]) compare premium demand
/// against this fraction.
pub const PREMIUM_CAPACITY_FRACTION: f64 = 0.5;

/// Counters of every predictive decision taken, vetoed, or gated —
/// surfaced in the `forecast` block of the simulator's metrics JSON.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PredictStats {
    /// Ticks on which the forecast showed a capacity deficit.
    pub proposed: u64,
    /// Capacity actions actually enacted (replications + spin-ups).
    pub enacted: u64,
    /// Proposals vetoed by the reactive live signal.
    pub vetoed: u64,
    /// Reactive drains blocked by the forecast gate.
    pub drain_vetoes: u64,
}

/// Summary of a run's forecasting quality and predictive activity (the
/// data behind the metrics JSON's `forecast` object).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictReport {
    /// One-bucket-ahead mean absolute error of the EWMA estimator.
    pub mae_ewma: f64,
    /// One-bucket-ahead mean absolute error of the Holt estimator.
    pub mae_holt: f64,
    /// One-bucket-ahead mean absolute error of the Holt-Winters estimator.
    pub mae_hw: f64,
    /// Rate buckets closed over the run.
    pub buckets: u64,
    /// Decision counters.
    pub stats: PredictStats,
    /// Was the forecaster in trace-oracle mode?
    pub oracle: bool,
}

/// The stateful predictive controller the kernel owns alongside the
/// reactive [`crate::coordinator::FleetController`].
#[derive(Debug, Clone)]
pub struct PredictiveController {
    /// Configuration this controller was built with.
    pub cfg: PredictConfig,
    /// The composed arrival-rate forecaster (fed from `Routed` events).
    pub forecaster: TrafficForecaster,
    /// The rate → instance-equivalents conversion.
    pub cap: CapacityModel,
    /// Decision counters.
    pub stats: PredictStats,
}

impl PredictiveController {
    /// Build a controller: estimators from `cfg`, capacity conversion
    /// from the caller-derived [`CapacityModel`].
    pub fn new(cfg: PredictConfig, cap: CapacityModel) -> PredictiveController {
        let forecaster = TrafficForecaster::new(
            cfg.bucket_s,
            Ewma::new(cfg.ewma_alpha),
            Holt::new(cfg.holt_alpha, cfg.holt_beta),
            HoltWinters::new(cfg.holt_alpha, cfg.holt_beta, cfg.hw_gamma, cfg.season_buckets),
            BurstDetector::new(cfg.burst_alpha, cfg.burst_sigma),
        );
        PredictiveController { cfg, forecaster, cap, stats: PredictStats::default() }
    }

    /// Forecasted capacity deficit (instance-equivalents) at horizon
    /// `h_s`, given `capacity_eq` of live capacity. Positive = the
    /// forecast says demand will exceed capacity when the horizon lands.
    pub fn deficit_at(&self, h_s: f64, capacity_eq: f64) -> f64 {
        self.cap.required_equivalents(self.forecaster.forecast(h_s)) - capacity_eq
    }

    /// Premium-first deficit: instance-equivalents the latency-sensitive
    /// class alone will lack at horizon `h_s`, judged against the share
    /// of live capacity it can claim *without waiting for preemption*
    /// ([`PREMIUM_CAPACITY_FRACTION`]). Exactly 0.0 minus the claimed
    /// capacity when no arrival was ever tagged premium — so in
    /// classless runs (which never call this) and in class-aware runs
    /// with no premium traffic the deficit never goes positive.
    pub fn premium_deficit_at(&self, h_s: f64, capacity_eq: f64) -> f64 {
        self.cap.required_equivalents(self.forecaster.forecast_premium(h_s))
            - capacity_eq * PREMIUM_CAPACITY_FRACTION
    }

    /// Precedence rule 2 (module docs): may the live signal veto a
    /// predictive proposal? Yes iff the fleet is deeply idle (mean
    /// outstanding below the reactive scale-in line), the forecasted
    /// deficit is weak (< `veto_deficit_eq`), and no burst is flagged.
    /// A strong forecast overrides idleness — that is the diurnal
    /// trough-before-crest case predictive scaling exists for.
    pub fn reactive_veto(
        &self,
        mean_outstanding: f64,
        scale_in_queue: f64,
        deficit_eq: f64,
    ) -> bool {
        mean_outstanding < scale_in_queue
            && deficit_eq < self.cfg.veto_deficit_eq
            && !self.forecaster.burst.is_burst()
    }

    /// Precedence rule 3 (module docs): should a reactive drain be
    /// blocked? Yes iff the forecast at the drain horizon needs more
    /// capacity than the fleet would have after the drain.
    pub fn block_drain(&self, capacity_after_eq: f64, horizon_s: f64) -> bool {
        self.deficit_at(horizon_s, capacity_after_eq) > 0.0
    }

    /// Summarize the run (the metrics JSON's `forecast` block).
    pub fn report(&self) -> PredictReport {
        let (mae_ewma, mae_holt, mae_hw) = self.forecaster.mae();
        PredictReport {
            mae_ewma,
            mae_holt,
            mae_hw,
            buckets: self.forecaster.buckets_closed(),
            stats: self.stats,
            oracle: self.forecaster.is_oracle(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller(mu: f64) -> PredictiveController {
        let cap = CapacityModel {
            mu_base_rps: mu,
            gamma: 0.05,
            n_layers: 40,
            target_util: 1.0,
            ref_eff_flops: 0.0, // homogeneous tests: factor pinned to 1.0
        };
        PredictiveController::new(
            PredictConfig { season_buckets: 8, ..Default::default() },
            cap,
        )
    }

    fn feed_rate(p: &mut PredictiveController, rate: f64, from: f64, to: f64) {
        let mut t = from;
        while t < to {
            p.forecaster.observe(t);
            t += 1.0 / rate;
        }
        p.forecaster.advance(to);
    }

    #[test]
    fn deficit_positive_when_forecast_exceeds_capacity() {
        let mut p = controller(10.0); // 1 eq serves 10 rps
        feed_rate(&mut p, 30.0, 0.0, 20.0);
        // 30 rps needs 3 eq; with 2 live the deficit is ≈ 1
        let d = p.deficit_at(1.0, 2.0);
        assert!((0.4..1.8).contains(&d), "deficit {d}");
        // abundant capacity → negative deficit
        assert!(p.deficit_at(1.0, 5.0) < 0.0);
    }

    #[test]
    fn drain_gate_blocks_only_when_capacity_is_needed() {
        let mut p = controller(10.0);
        feed_rate(&mut p, 25.0, 0.0, 20.0);
        // 25 rps needs 2.5 eq: draining from 3 → 2 would undershoot
        assert!(p.block_drain(2.0, 8.0));
        // draining from 5 → 4 keeps headroom
        assert!(!p.block_drain(4.0, 8.0));
    }

    #[test]
    fn reactive_veto_requires_idle_and_weak_and_no_burst() {
        let mut p = controller(10.0);
        feed_rate(&mut p, 5.0, 0.0, 20.0);
        // idle live signal + weak deficit → veto
        assert!(p.reactive_veto(0.5, 2.0, 0.2));
        // strong deficit overrides idleness (the trough-before-crest case)
        assert!(!p.reactive_veto(0.5, 2.0, 0.8));
        // live pressure present → no veto
        assert!(!p.reactive_veto(5.0, 2.0, 0.2));
        // burst flag overrides the veto even with a weak deficit
        let mut t = 20.0;
        while t < 22.0 {
            p.forecaster.observe(t);
            t += 1.0 / 40.0;
        }
        p.forecaster.advance(22.0);
        assert!(p.forecaster.burst.is_burst());
        assert!(!p.reactive_veto(0.5, 2.0, 0.2));
    }

    #[test]
    fn report_carries_stats_and_mae() {
        let mut p = controller(10.0);
        feed_rate(&mut p, 12.0, 0.0, 10.0);
        p.stats.proposed = 3;
        p.stats.enacted = 2;
        p.stats.vetoed = 1;
        let r = p.report();
        assert_eq!(r.stats.proposed, 3);
        assert_eq!(r.buckets, 10);
        assert!(!r.oracle);
        assert!(r.mae_ewma >= 0.0 && r.mae_holt >= 0.0 && r.mae_hw >= 0.0);
    }

    #[test]
    fn default_config_is_sane() {
        let c = PredictConfig::default();
        assert!(c.bucket_s > 0.0);
        assert!((0.0..=1.0).contains(&c.target_util));
        assert!(c.spin_deficit_eq > c.veto_deficit_eq);
        // the premium-first floor is deliberately below the mixed floor
        assert!(c.premium_spin_deficit_eq < c.spin_deficit_eq);
        assert!(!c.oracle);
    }

    #[test]
    fn premium_deficit_tracks_tagged_share_only() {
        use crate::workload::SloClass;
        let mut p = controller(10.0); // 1 eq serves 10 rps
        // 30 rps total, every other arrival premium → premium ≈ 15 rps
        let mut t = 0.0;
        let mut i = 0u64;
        while t < 20.0 {
            p.forecaster.observe(t);
            p.forecaster.observe_class(if i % 2 == 0 {
                SloClass::LatencySensitive
            } else {
                SloClass::BestEffort
            });
            i += 1;
            t += 1.0 / 30.0;
        }
        p.forecaster.advance(20.0);
        // premium needs ≈ 1.5 eq; with 2 eq live it can claim only
        // 2 × PREMIUM_CAPACITY_FRACTION = 1 eq → positive deficit, while
        // the mixed deficit at 3 eq of capacity is already negative
        assert!(p.premium_deficit_at(1.0, 2.0) > 0.0);
        assert!(p.deficit_at(1.0, 3.5) < 0.0);
        assert!(p.premium_deficit_at(1.0, 4.0) < 0.0, "abundant capacity clears it");
        // untagged controller: premium demand is exactly zero
        let mut q = controller(10.0);
        feed_rate(&mut q, 30.0, 0.0, 20.0);
        assert!(q.premium_deficit_at(1.0, 1.0) < 0.0);
        assert_eq!(q.forecaster.forecast_premium(1.0), 0.0);
    }
}
