//! Model architecture description and the module taxonomy.
//!
//! In the paper (§1 footnote 1) "modules" are: decoder layers, attention,
//! feed-forward network, projections, and the KV cache. This module defines
//! that taxonomy ([`ModuleKind`]) plus the architectural constants
//! ([`ModelConfig`]) shared with the Python compile path via
//! `artifacts/manifest.json`; [`cost`] implements the paper's §3.3 resource
//! arithmetic (Table 1).

pub mod cost;

use crate::util::json::Json;

/// Architectural description of a LLaMA-style decoder-only model.
///
/// Mirrors `python/compile/configs.py::ModelConfig`; parsed from the
/// manifest so there is exactly one source of truth.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Human-readable model name (e.g. `llama2-13b`).
    pub name: String,
    /// Token vocabulary size.
    pub vocab_size: usize,
    /// Hidden (embedding) dimension.
    pub d_model: usize,
    /// Attention heads per layer.
    pub n_heads: usize,
    /// Decoder layers.
    pub n_layers: usize,
    /// Feed-forward inner dimension.
    pub d_ff: usize,
}

impl ModelConfig {
    /// Per-head dimension (`d_model / n_heads`).
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Parse from the manifest JSON written by the Python compile path.
    pub fn from_json(j: &Json) -> ModelConfig {
        ModelConfig {
            name: j.req("name").as_str().expect("name").to_string(),
            vocab_size: j.req("vocab_size").as_usize().expect("vocab_size"),
            d_model: j.req("d_model").as_usize().expect("d_model"),
            n_heads: j.req("n_heads").as_usize().expect("n_heads"),
            n_layers: j.req("n_layers").as_usize().expect("n_layers"),
            d_ff: j.req("d_ff").as_usize().expect("d_ff"),
        }
    }

    /// The paper's LLaMA2-13B reference (d=5120, ff=13824, 40 layers).
    pub fn llama2_13b() -> ModelConfig {
        ModelConfig {
            name: "llama2-13b".into(),
            vocab_size: 32000,
            d_model: 5120,
            n_heads: 40,
            n_layers: 40,
            d_ff: 13824,
        }
    }

    /// The paper's LLaMA2-70B reference (d=8192, ff=28672, 80 layers).
    pub fn llama2_70b() -> ModelConfig {
        ModelConfig {
            name: "llama2-70b".into(),
            vocab_size: 32000,
            d_model: 8192,
            n_heads: 64,
            n_layers: 80,
            d_ff: 28672,
        }
    }

    /// The tiny config actually lowered + executed on CPU PJRT.
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny-llama".into(),
            vocab_size: 512,
            d_model: 64,
            n_heads: 4,
            n_layers: 4,
            d_ff: 172,
        }
    }
}

/// The paper's module taxonomy — the units of replication and migration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModuleKind {
    /// Token embedding table.
    Embed,
    /// A whole transformer decoder layer (the primary scaling unit).
    DecoderLayer,
    /// The attention block of a layer (QKVO + core).
    Attn,
    /// A single attention projection (the finest weight-bearing unit).
    QProj,
    /// The key projection of a layer's attention block.
    KProj,
    /// The value projection of a layer's attention block.
    VProj,
    /// The output projection of a layer's attention block.
    OProj,
    /// The SwiGLU feed-forward block.
    Ffn,
    /// One FFN projection.
    GateProj,
    /// The up projection of a layer's FFN block.
    UpProj,
    /// The down projection of a layer's FFN block.
    DownProj,
    /// The per-layer KV cache (memory-intensive, compute-free).
    KvCache,
    /// Final norm + output projection.
    LmHead,
}

impl ModuleKind {
    /// All weight-bearing module kinds (everything except the KV cache).
    pub const WEIGHT_BEARING: [ModuleKind; 12] = [
        ModuleKind::Embed,
        ModuleKind::DecoderLayer,
        ModuleKind::Attn,
        ModuleKind::QProj,
        ModuleKind::KProj,
        ModuleKind::VProj,
        ModuleKind::OProj,
        ModuleKind::Ffn,
        ModuleKind::GateProj,
        ModuleKind::UpProj,
        ModuleKind::DownProj,
        ModuleKind::LmHead,
    ];

    /// Is this module memory-intensive rather than compute-intensive?
    /// (§3.3: the KV cache needs "significant memory but minimal
    /// computation"; everything else has high GFLOPs/MB density.)
    pub fn memory_intensive(self) -> bool {
        matches!(self, ModuleKind::KvCache)
    }

    /// The paper's dotted module path (e.g. `self_attn.q_proj`).
    pub fn name(self) -> &'static str {
        match self {
            ModuleKind::Embed => "embed",
            ModuleKind::DecoderLayer => "decoder_layer",
            ModuleKind::Attn => "self_attn",
            ModuleKind::QProj => "self_attn.q_proj",
            ModuleKind::KProj => "self_attn.k_proj",
            ModuleKind::VProj => "self_attn.v_proj",
            ModuleKind::OProj => "self_attn.o_proj",
            ModuleKind::Ffn => "ffn",
            ModuleKind::GateProj => "ffn.gate_proj",
            ModuleKind::UpProj => "ffn.up_proj",
            ModuleKind::DownProj => "ffn.down_proj",
            ModuleKind::KvCache => "kv_cache",
            ModuleKind::LmHead => "lm_head",
        }
    }
}

/// Identifies a concrete module instance inside a model: `(kind, layer)`.
/// Layer is `None` for embed / lm_head.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModuleId {
    /// What kind of module this is.
    pub kind: ModuleKind,
    /// Which decoder layer it belongs to (`None` for embed / lm_head).
    pub layer: Option<usize>,
}

impl ModuleId {
    /// A per-layer module: `(kind, Some(layer))`.
    pub fn layer(kind: ModuleKind, layer: usize) -> ModuleId {
        ModuleId { kind, layer: Some(layer) }
    }

    /// A layer-less module (embed / lm_head): `(kind, None)`.
    pub fn global(kind: ModuleKind) -> ModuleId {
        ModuleId { kind, layer: None }
    }
}

impl std::fmt::Display for ModuleId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.layer {
            Some(l) => write!(f, "layers.{l}.{}", self.kind.name()),
            None => write!(f, "{}", self.kind.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dim_divides() {
        assert_eq!(ModelConfig::llama2_13b().head_dim(), 128);
        assert_eq!(ModelConfig::tiny().head_dim(), 16);
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"name":"x","vocab_size":10,"d_model":8,"n_heads":2,
                "n_layers":3,"d_ff":16,"head_dim":4}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j);
        assert_eq!(c.d_model, 8);
        assert_eq!(c.head_dim(), 4);
    }

    #[test]
    fn module_display() {
        assert_eq!(
            ModuleId::layer(ModuleKind::Attn, 3).to_string(),
            "layers.3.self_attn"
        );
        assert_eq!(ModuleId::global(ModuleKind::LmHead).to_string(), "lm_head");
    }

    #[test]
    fn only_kv_cache_is_memory_intensive() {
        for k in ModuleKind::WEIGHT_BEARING {
            assert!(!k.memory_intensive(), "{k:?}");
        }
        assert!(ModuleKind::KvCache.memory_intensive());
    }
}
