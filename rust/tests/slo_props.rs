//! SLO-class fairness property harness: the pinning tests for
//! multi-tenant priority routing, weighted fair queuing, and mid-step
//! preemption, run over randomized arrival tapes with the `util::prop`
//! harness (replay any failure with `PROP_SEED=<seed> PROP_CASE=<i>`).
//!
//! * **WFQ shares** — with both classes continuously backlogged, the
//!   long-run service shares of the weighted-fair parked queue stay
//!   within the deficit-scheme bound of the configured weight ratio,
//!   for every randomized weight pair and tape length.
//! * **Strict priority no-inversion** — at equal arrival times a parked
//!   best-effort request is never served while any latency-sensitive
//!   request is parked, across randomized park/serve tapes.
//! * **Preemption conservation** — full class-aware simulations (with
//!   mid-step preemption, shed re-routing, and a mid-run device failure)
//!   never lose or duplicate a request: unique completions plus requests
//!   parked at the deadline equal the trace length, and every completion
//!   retains its original arrival time and SLO class.

use std::collections::{BTreeMap, BTreeSet};

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec};
use cocoserve::coordinator::{FleetConfig, RoutePolicy, Router, RouterConfig};
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, Simulation};
use cocoserve::util::{prop, rng::Rng};
use cocoserve::workload::{FailureSchedule, Request, SloClass, Trace};

const LS: SloClass = SloClass::LatencySensitive;
const BE: SloClass = SloClass::BestEffort;

fn req(id: u64, arrival_s: f64, class: SloClass) -> Request {
    Request { id, arrival_s, prompt_tokens: 8, output_tokens: 4, class }
}

#[test]
fn prop_wfq_long_run_shares_track_weights() {
    prop::check(
        "wfq-shares-track-weights",
        |r: &mut Rng| {
            let wp = 1 + r.below(8) as u32;
            let wb = 1 + r.below(8) as u32;
            let rounds = 400 + r.below(400) as usize;
            (wp, wb, rounds)
        },
        |&(wp, wb, rounds)| {
            let mut router = Router::new(RouterConfig {
                policy: RoutePolicy::WeightedFair,
                wfq_premium_weight: wp,
                wfq_be_weight: wb,
                ..RouterConfig::default()
            });
            let mut next_id = 0u64;
            for class in [LS, LS, BE, BE] {
                router.park(req(next_id, 0.0, class), 0.0, false);
                next_id += 1;
            }
            let mut served = [0usize; 2];
            for _ in 0..rounds {
                let idx = router.next_parked().ok_or("parked queue ran dry")?;
                let taken = router.take_parked(idx);
                served[Router::class_idx(taken.req.class)] += 1;
                // immediately re-park the same class: both classes stay
                // continuously backlogged, the regime WFQ guarantees
                // shares in
                router.park(req(next_id, 0.0, taken.req.class), 0.0, false);
                next_id += 1;
            }
            let want = f64::from(wp) / f64::from(wp + wb);
            let got = served[0] as f64 / rounds as f64;
            // Deficit bound: the two virtual clocks never drift apart by
            // more than one dispatch's worth of virtual time, so the
            // share error shrinks as 1/rounds.
            let bound = f64::from(wp + wb) / rounds as f64 + 0.01;
            if (got - want).abs() > bound {
                return Err(format!(
                    "premium share {got:.4} strayed from {want:.4} \
                     (weights {wp}:{wb}, {rounds} rounds, bound {bound:.4})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_strict_priority_admits_no_inversion() {
    // Randomized park/serve tapes, every request at the same arrival
    // time: whenever the strict-priority queue serves a best-effort
    // entry, no latency-sensitive entry may be parked — a premium
    // request can never queue behind a best-effort one.
    prop::check(
        "strict-priority-no-inversion",
        |r: &mut Rng| {
            let ops: Vec<(bool, bool)> = (0..120)
                .map(|_| (r.f64() < 0.55, r.f64() < 0.5))
                .collect();
            ops
        },
        |ops| {
            let mut router = Router::new(RouterConfig {
                policy: RoutePolicy::StrictPriority,
                ..RouterConfig::default()
            });
            let mut next_id = 0u64;
            for &(is_park, premium) in ops {
                if is_park {
                    router.park(req(next_id, 0.0, if premium { LS } else { BE }), 0.0, false);
                    next_id += 1;
                } else if let Some(idx) = router.next_parked() {
                    let premium_waiting = router.parked_of(LS) > 0;
                    let taken = router.take_parked(idx);
                    if premium_waiting && taken.req.class != LS {
                        return Err(format!(
                            "inversion: served best-effort request {} while \
                             a latency-sensitive request was parked",
                            taken.req.id
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_preemption_conserves_requests() {
    // Full class-aware simulations over randomized classed burst tapes:
    // strict-priority or WFQ routing, mid-step preemption armed, shed
    // re-routing on, and a mid-run device failure so every shed path
    // (Preempt, FailBatch, DeviceFailed) funnels through the same
    // conservation machinery. The audit block's parked remainder closes
    // the accounting: completed + unrouted == arrivals, no id twice,
    // and every completion keeps its original arrival time and class.
    prop::check(
        "preemption-conservation",
        |r: &mut Rng| {
            let seed = r.next_u64();
            let strict = r.f64() < 0.5;
            let rps = 4.0 + r.f64() * 6.0;
            (seed, strict, rps)
        },
        |&(seed, strict, rps)| {
            let duration = 5.0;
            let trace = Trace::burst_classed(rps, duration, seed);
            let by_id: BTreeMap<u64, (u64, SloClass)> = trace
                .requests
                .iter()
                .map(|r| (r.id, (r.arrival_s.to_bits(), r.class)))
                .collect();
            let cfg = SimConfig::paper_13b();
            let cluster = Cluster::homogeneous(5, DeviceSpec::a100_40gb());
            let policy = baselines::cocoserve(32);
            let placements: Vec<_> = (0..2)
                .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
                .collect();
            let setup = FleetSetup {
                router: RouterConfig {
                    policy: if strict {
                        RoutePolicy::StrictPriority
                    } else {
                        RoutePolicy::WeightedFair
                    },
                    admission_limit: Some(64),
                    be_admission_limit: Some(48),
                    reroute_on_shed: true,
                    ..RouterConfig::default()
                },
                fleet: Some(FleetConfig::elastic(2, 4, policy)),
                ..Default::default()
            };
            // device 1 dies mid-run; instance 0 on device 0 survives, so
            // the run keeps serving and the shed work re-routes
            let r = Simulation::with_fleet(cfg, cluster, placements, setup)
                .with_failures(FailureSchedule::at(&[(2.5, 1)]))
                .run(&trace, duration);
            let mut seen = BTreeSet::new();
            for m in &r.monitors {
                for c in m.completions() {
                    if !seen.insert(c.request_id) {
                        return Err(format!("request {} completed twice", c.request_id));
                    }
                    let &(arrival_bits, class) = by_id
                        .get(&c.request_id)
                        .ok_or_else(|| format!("unknown id {}", c.request_id))?;
                    if c.arrival_s.to_bits() != arrival_bits {
                        return Err(format!(
                            "request {} lost its arrival time: {} recorded",
                            c.request_id, c.arrival_s
                        ));
                    }
                    if c.class != class {
                        return Err(format!(
                            "request {} lost its SLO class across re-routing",
                            c.request_id
                        ));
                    }
                }
            }
            let unrouted = r
                .audit
                .as_ref()
                .ok_or("failure runs must carry an audit block")?
                .unrouted_at_end;
            if seen.len() + unrouted != trace.len() {
                return Err(format!(
                    "conservation broke: {} completed + {} unrouted != {} arrivals",
                    seen.len(),
                    unrouted,
                    trace.len()
                ));
            }
            let slo = r.slo.as_ref().ok_or("class-aware runs must carry the slo block")?;
            if slo.premium_completed + slo.be_completed != seen.len() {
                return Err(format!(
                    "slo block miscounts: {} + {} != {}",
                    slo.premium_completed,
                    slo.be_completed,
                    seen.len()
                ));
            }
            Ok(())
        },
    );
}
