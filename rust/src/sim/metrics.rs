//! Latency / throughput / utilization accounting for simulation runs.
//!
//! [`SimReport`] is the single output artifact of [`crate::sim::Simulation`]:
//! per-instance monitors, device utilization, OOM and scaling counters, and
//! memory peaks. [`SimReport::to_json`] renders it as a **deterministic**
//! metrics document (BTreeMap key order, shortest-roundtrip float printing)
//! — two runs with the same seed and trace produce byte-identical JSON,
//! which the golden-replay test and the fig10/fig11 benches assert.

use crate::coordinator::{AuditLog, FleetEvent};
use crate::forecast::PredictReport;
use crate::mempress::MempressReport;
use crate::monitor::Monitor;
use crate::placement::Placement;
use crate::util::json::{self, Json};
use crate::util::stats::P2Quantile;

/// Lifecycle phase of one logged scaling-op event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpPhase {
    /// The op's transfer began (replication overlaps serving from here).
    Started,
    /// The op's effects were applied to the ledgers + placement.
    Completed,
    /// The op failed; the whole plan was rolled back at this timestamp.
    Aborted,
}

impl OpPhase {
    /// Stable name used in the golden metrics JSON.
    pub fn name(self) -> &'static str {
        match self {
            OpPhase::Started => "started",
            OpPhase::Completed => "completed",
            OpPhase::Aborted => "aborted",
        }
    }
}

/// One timestamped scaling-op lifecycle record — the evidence that plans
/// execute *in flight* (op events interleave with request completions in
/// the golden-replay tests).
#[derive(Debug, Clone, PartialEq)]
pub struct OpEvent {
    /// Simulated time of the phase transition.
    pub t: f64,
    /// Instance whose plan the op belongs to.
    pub instance: usize,
    /// Index of the op within its plan.
    pub op_idx: usize,
    /// Lifecycle phase recorded.
    pub phase: OpPhase,
    /// `ModuleOp::describe()` of the op.
    pub desc: String,
}

/// Counters + event log for executed scaling operations (Algorithm 1 / 2
/// plans).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScaleStats {
    /// Scale-up plans admitted (Algorithm 1 rounds).
    pub scale_ups: u64,
    /// Scale-down plans admitted or executed (Algorithm 2 rounds).
    pub scale_downs: u64,
    /// Total transfer time consumed by scaling operations (background).
    pub op_time_s: f64,
    /// Plans aborted mid-flight (rolled back after an op failed against
    /// the live ledgers).
    pub plans_aborted: u64,
    /// Timestamped op lifecycle log.
    pub events: Vec<OpEvent>,
}

/// The failure-domain audit trail attached to a chaos run: the
/// append-only record stream plus the end-of-run conservation
/// denominator the chaos tests need (requests still parked at the drain
/// deadline are neither completed nor shed — they must be accounted for
/// before "no request lost" can be asserted).
#[derive(Debug, Clone, PartialEq)]
pub struct AuditBlock {
    /// Every module op, failure, recovery decision, and rollback —
    /// appended in event order, replayable and byte-for-byte diffable.
    pub log: AuditLog,
    /// Requests still parked in the router when the run ended (capacity
    /// never recovered enough to place them before the drain deadline).
    pub unrouted_at_end: usize,
}

/// Per-SLO-class serving outcome attached to class-aware runs: how each
/// class fared (completions, SLO attainment, routing share) plus the
/// number of mid-step preemptions the premium class triggered. Only
/// assembled when the routing policy is class-aware, so classless runs
/// carry no `slo` key and stay byte-identical to pre-class documents.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloBlock {
    /// Latency-sensitive requests completed.
    pub premium_completed: usize,
    /// Fraction of latency-sensitive completions within their monitor's
    /// SLO (1.0 when the class completed nothing).
    pub premium_slo_attainment: f64,
    /// Best-effort requests completed.
    pub be_completed: usize,
    /// Fraction of best-effort completions within their monitor's SLO
    /// (1.0 when the class completed nothing).
    pub be_slo_attainment: f64,
    /// Best-effort batches interrupted at a token boundary so a waiting
    /// latency-sensitive request could be admitted.
    pub preemptions: u64,
    /// First-time routes granted to latency-sensitive requests.
    pub premium_routes: u64,
    /// First-time routes granted to best-effort requests.
    pub be_routes: u64,
}

/// Aggregated outcome of a simulation run.
#[derive(Debug)]
pub struct SimReport {
    /// Simulated wall time the run covered (trace + drain).
    pub duration_s: f64,
    /// Events the kernel popped (wall-clock throughput denominator for
    /// the fleet-scale bench). Deliberately NOT part of [`SimReport::to_json`]
    /// — the golden-replay document is a serving-metrics contract.
    pub events_processed: u64,
    /// Serving steps started (prefill + decode) across the fleet. Also
    /// excluded from the golden JSON.
    pub steps_started: u64,
    /// Device-seconds billed over the run: each device bills for every
    /// simulated second during which it holds at least one module of a
    /// live instance (weights, replica, or migrated module). The cost
    /// denominator of the paper's 46 % claim (fig1 bench).
    pub device_seconds: f64,
    /// First-time routing decisions the coordinator made (one per
    /// delivered trace arrival).
    pub routes: u64,
    /// Re-routing decisions for requests shed by OOM handling.
    pub reroutes: u64,
    /// Timestamped fleet lifecycle log (spin-up / drain / release).
    pub fleet_events: Vec<FleetEvent>,
    /// Per-instance monitors (completion records, SLO accounting).
    pub monitors: Vec<Monitor>,
    /// (device, compute utilization, mem frac at end).
    pub device_util: Vec<(usize, f64, f64)>,
    /// Per-device peak resident bytes over the run.
    pub device_peak_bytes: Vec<f64>,
    /// OOM events across device ledgers and instance monitors.
    pub total_oom_events: u64,
    /// Scale-up plans admitted over the run.
    pub scale_ups: u64,
    /// Scale-down plans admitted or executed over the run.
    pub scale_downs: u64,
    /// Unique requests ever caught in an OOM failure.
    pub oom_victims: usize,
    /// Total transfer time consumed by scaling operations (background).
    pub scale_op_time_s: f64,
    /// Total bytes resident at peak (cost/memory comparisons, Fig. 10).
    pub peak_mem_bytes: f64,
    /// Peak KV accounting per instance over the run (Fig. 9).
    pub kv_stats: Vec<crate::kvcache::KvStats>,
    /// Per-instance final placements (inspection/tests).
    pub placements: Vec<Placement>,
    /// Per-instance final batch sizes.
    pub batch_sizes: Vec<usize>,
    /// Plans aborted mid-flight (rolled back).
    pub plans_aborted: u64,
    /// Timestamped scaling-op lifecycle log (in-flight execution trace).
    pub op_events: Vec<OpEvent>,
    /// Forecast quality + predictive-action summary. `None` when no
    /// predictor was configured — and then the metrics JSON carries no
    /// `forecast` key at all, keeping reactive-only documents
    /// byte-identical to the pre-forecast kernel.
    pub forecast: Option<PredictReport>,
    /// Memory-pressure governor summary (fleet-wide sums of every
    /// instance's escalation-ladder counters plus the number of layers
    /// still quantized at the end of the run). `None` when no governor
    /// was configured — same additive-key discipline as `forecast`.
    pub mempress: Option<MempressReport>,
    /// Failure-domain audit trail. `None` when no failure schedule was
    /// configured — and then the metrics JSON carries no `audit` key at
    /// all, keeping failure-free documents byte-identical to the
    /// pre-chaos kernel (same additive-key discipline as `forecast`).
    pub audit: Option<AuditBlock>,
    /// Per-SLO-class outcome summary. `None` when the routing policy is
    /// not class-aware — and then the metrics JSON carries no `slo` key
    /// at all, keeping classless documents byte-identical to the
    /// pre-class kernel (same additive-key discipline as `audit`).
    pub slo: Option<SloBlock>,
    /// Streaming per-window telemetry timeline. `None` unless
    /// [`crate::sim::SimConfig::telemetry`] configured a window — and
    /// then the metrics JSON carries no `timeline` key at all, keeping
    /// telemetry-off documents byte-identical to the pre-telemetry
    /// kernel (same additive-key discipline as `forecast`).
    pub timeline: Option<crate::telemetry::TimelineBlock>,
    /// Recorded span buffer (`None` with telemetry off). Deliberately
    /// NOT part of [`SimReport::to_json`] — the trace exports through
    /// [`SimReport::chrome_trace`] as its own Perfetto-loadable file,
    /// never into the golden metrics document.
    pub trace: Option<crate::telemetry::TraceBuffer>,
    /// Kernel self-profile (per-event-kind wall-time/alloc histogram).
    /// Also excluded from the golden JSON — wall-clock must never enter
    /// the replayed surface; `BENCH_fleet.json` is its home.
    pub profile: Option<crate::telemetry::profiler::KernelProfile>,
}

impl SimReport {
    /// All completions' end-to-end latencies as an exact-sample summary.
    /// Materializes (and, on percentile reads, sorts) a merged copy —
    /// fine for bounded experiments; bench-scale percentile tracking
    /// should use [`SimReport::latency_p2`] instead.
    pub fn merged_latency(&self) -> crate::util::stats::Summary {
        let mut s = crate::util::stats::Summary::new();
        for m in &self.monitors {
            for c in m.completions() {
                s.add(c.e2e_latency());
            }
        }
        s
    }

    /// Streaming end-to-end latency quantile across every monitor via the
    /// O(1)-memory P² estimator: no merged sample vector, no sort — the
    /// fleet-bench path for p50/p99 over 500k+ completions. The golden
    /// metrics JSON keeps the exact per-monitor summaries; this is the
    /// reporting path.
    pub fn latency_p2(&self, q: f64) -> f64 {
        self.latency_p2s(&[q])[0]
    }

    /// Several streaming quantiles in **one** pass over the completions
    /// (one P² estimator per requested quantile) — the `[p50, p99]`
    /// bench path without re-iterating 500k+ records per read.
    pub fn latency_p2s(&self, qs: &[f64]) -> Vec<f64> {
        let mut ps: Vec<P2Quantile> = qs.iter().map(|&q| P2Quantile::new(q)).collect();
        for m in &self.monitors {
            for c in m.completions() {
                let lat = c.e2e_latency();
                for p in &mut ps {
                    p.add(lat);
                }
            }
        }
        ps.iter().map(|p| p.value()).collect()
    }

    /// Output-token throughput summed across every instance (tokens/s).
    pub fn total_throughput_tps(&self) -> f64 {
        self.monitors
            .iter()
            .map(|m| m.throughput_tokens_per_s(self.duration_s))
            .sum()
    }

    /// Completed requests across every instance.
    pub fn total_completed(&self) -> usize {
        self.monitors.iter().map(|m| m.completions().len()).sum()
    }

    /// Fraction of completions within their monitor's SLO, fleet-wide.
    pub fn slo_attainment(&self) -> f64 {
        let (ok, total) = self.monitors.iter().fold((0usize, 0usize), |(o, t), m| {
            let good = m
                .completions()
                .iter()
                .filter(|c| c.e2e_latency() <= m.slo_latency_s)
                .count();
            (o + good, t + m.completions().len())
        });
        if total == 0 {
            1.0
        } else {
            ok as f64 / total as f64
        }
    }

    /// Fraction of requests caught in an OOM failure (Fig. 11a).
    pub fn oom_rate(&self) -> f64 {
        let total = self.total_completed() as f64;
        if total == 0.0 {
            0.0
        } else {
            self.oom_victims as f64 / total
        }
    }

    /// Deterministic metrics document: same seed + trace ⇒ byte-identical
    /// output (the golden-replay contract).
    pub fn to_json(&self) -> Json {
        let instances = json::arr(self.monitors.iter().enumerate().map(|(i, m)| {
            let o = vec![
                ("monitor", m.metrics_json(self.duration_s)),
                ("batch_size", json::num(self.batch_sizes[i] as f64)),
                (
                    "kv_peak_reserved_bytes",
                    json::num(self.kv_stats[i].reserved_bytes),
                ),
                (
                    "p_vector",
                    json::arr(
                        self.placements[i]
                            .p_vector()
                            .into_iter()
                            .map(|p| json::num(p as f64)),
                    ),
                ),
                (
                    "transitions",
                    json::num(self.placements[i].transition_count() as f64),
                ),
            ];
            json::obj(o)
        }));
        let devices = json::arr(self.device_util.iter().map(|&(d, util, mem)| {
            json::obj(vec![
                ("device", json::num(d as f64)),
                ("mem_frac", json::num(mem)),
                ("peak_bytes", json::num(self.device_peak_bytes[d])),
                ("util", json::num(util)),
            ])
        }));
        let op_events = json::arr(self.op_events.iter().map(|e| {
            json::obj(vec![
                ("desc", json::s(&e.desc)),
                ("instance", json::num(e.instance as f64)),
                ("op", json::num(e.op_idx as f64)),
                ("phase", json::s(e.phase.name())),
                ("t", json::num(e.t)),
            ])
        }));
        let fleet_events = json::arr(self.fleet_events.iter().map(|e| {
            json::obj(vec![
                ("instance", json::num(e.instance as f64)),
                ("phase", json::s(e.phase.name())),
                ("t", json::num(e.t)),
            ])
        }));
        let mut pairs = vec![
            ("completed", json::num(self.total_completed() as f64)),
            ("device_seconds", json::num(self.device_seconds)),
            ("devices", devices),
            ("duration_s", json::num(self.duration_s)),
            ("fleet_events", fleet_events),
            ("instances", instances),
            ("reroutes", json::num(self.reroutes as f64)),
            ("routes", json::num(self.routes as f64)),
            ("oom_events", json::num(self.total_oom_events as f64)),
            ("oom_rate", json::num(self.oom_rate())),
            ("oom_victims", json::num(self.oom_victims as f64)),
            ("op_events", op_events),
            ("peak_mem_bytes", json::num(self.peak_mem_bytes)),
            ("plans_aborted", json::num(self.plans_aborted as f64)),
            ("scale_downs", json::num(self.scale_downs as f64)),
            ("scale_op_time_s", json::num(self.scale_op_time_s)),
            ("scale_ups", json::num(self.scale_ups as f64)),
            ("slo_attainment", json::num(self.slo_attainment())),
            ("throughput_tps", json::num(self.total_throughput_tps())),
        ];
        // strictly additive: the `forecast` key exists only when a
        // predictor was configured, so reactive-only documents stay
        // byte-identical to the pre-forecast kernel
        if let Some(f) = &self.forecast {
            pairs.push((
                "forecast",
                json::obj(vec![
                    ("buckets", json::num(f.buckets as f64)),
                    ("drain_vetoes", json::num(f.stats.drain_vetoes as f64)),
                    ("enacted", json::num(f.stats.enacted as f64)),
                    ("mae_ewma", json::num(f.mae_ewma)),
                    ("mae_holt", json::num(f.mae_holt)),
                    ("mae_holt_winters", json::num(f.mae_hw)),
                    ("oracle", json::num(f64::from(u8::from(f.oracle)))),
                    ("proposed", json::num(f.stats.proposed as f64)),
                    ("vetoed", json::num(f.stats.vetoed as f64)),
                ]),
            ));
        }
        // same discipline for the memory-pressure governor: no governor,
        // no `mempress` key, byte-identical pre-governor documents
        if let Some(m) = &self.mempress {
            pairs.push((
                "mempress",
                json::obj(vec![
                    ("episodes", json::num(m.episodes as f64)),
                    ("escalations", json::num(m.escalations as f64)),
                    ("kv_grows", json::num(m.kv_grows as f64)),
                    ("kv_shrinks", json::num(m.kv_shrinks as f64)),
                    ("pool_granted_bytes", json::num(m.pool_granted_bytes)),
                    ("pool_reclaimed_bytes", json::num(m.pool_reclaimed_bytes)),
                    ("quality_penalty", json::num(m.quality_penalty)),
                    ("quantized_layers", json::num(m.quantized_layers as f64)),
                    ("sheds_averted", json::num(m.sheds_averted as f64)),
                    ("swap_freed_bytes", json::num(m.swap_freed_bytes)),
                    ("swap_requests", json::num(m.swap_requests as f64)),
                    ("swaps_applied", json::num(m.swaps_applied as f64)),
                ]),
            ));
        }
        // and for the failure-domain audit trail: no failure schedule,
        // no `audit` key, byte-identical pre-chaos documents
        if let Some(a) = &self.audit {
            pairs.push((
                "audit",
                json::obj(vec![
                    ("records", a.log.to_json()),
                    ("unrouted_at_end", json::num(a.unrouted_at_end as f64)),
                ]),
            ));
        }
        // and for the SLO-class summary: classless routing policy, no
        // `slo` key, byte-identical pre-class documents
        if let Some(s) = &self.slo {
            pairs.push((
                "slo",
                json::obj(vec![
                    ("be_completed", json::num(s.be_completed as f64)),
                    ("be_routes", json::num(s.be_routes as f64)),
                    ("be_slo_attainment", json::num(s.be_slo_attainment)),
                    ("preemptions", json::num(s.preemptions as f64)),
                    ("premium_completed", json::num(s.premium_completed as f64)),
                    ("premium_routes", json::num(s.premium_routes as f64)),
                    (
                        "premium_slo_attainment",
                        json::num(s.premium_slo_attainment),
                    ),
                ]),
            ));
        }
        // and for the telemetry timeline: telemetry off (or windowing
        // disabled), no `timeline` key, byte-identical pre-telemetry
        // documents. The span trace and kernel profile never appear
        // here at all — see the field docs.
        if let Some(t) = &self.timeline {
            pairs.push(("timeline", t.to_json()));
        }
        json::obj(pairs)
    }

    /// Render the recorded span buffer as Chrome trace-event JSON
    /// (`None` when telemetry was off). Load the serialized value in
    /// [ui.perfetto.dev](https://ui.perfetto.dev) or `chrome://tracing`.
    pub fn chrome_trace(&self) -> Option<Json> {
        self.trace.as_ref().map(crate::telemetry::export::chrome_trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::Completion;

    fn tiny_report() -> SimReport {
        let mut m = Monitor::new(10.0);
        m.record(Completion {
            request_id: 0,
            arrival_s: 0.0,
            finish_s: 2.5,
            prompt_tokens: 10,
            output_tokens: 20,
            class: crate::workload::SloClass::default(),
        });
        SimReport {
            duration_s: 10.0,
            events_processed: 0,
            steps_started: 0,
            device_seconds: 10.0,
            routes: 1,
            reroutes: 0,
            fleet_events: vec![crate::coordinator::FleetEvent {
                t: 0.5,
                instance: 0,
                phase: crate::coordinator::FleetPhase::SpinUp,
            }],
            monitors: vec![m],
            device_util: vec![(0, 0.5, 0.25)],
            device_peak_bytes: vec![1e9],
            total_oom_events: 0,
            scale_ups: 1,
            scale_downs: 0,
            oom_victims: 0,
            scale_op_time_s: 0.3,
            peak_mem_bytes: 2e9,
            kv_stats: vec![Default::default()],
            placements: vec![Placement::single_device(4, 0)],
            batch_sizes: vec![8],
            plans_aborted: 0,
            op_events: vec![OpEvent {
                t: 1.5,
                instance: 0,
                op_idx: 0,
                phase: OpPhase::Completed,
                desc: "replicate L0->d1".into(),
            }],
            forecast: None,
            mempress: None,
            audit: None,
            slo: None,
            timeline: None,
            trace: None,
            profile: None,
        }
    }

    #[test]
    fn json_is_deterministic_and_parses() {
        let a = tiny_report().to_json().to_string();
        let b = tiny_report().to_json().to_string();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(parsed.req("completed").as_usize(), Some(1));
        assert_eq!(parsed.req("scale_ups").as_usize(), Some(1));
        assert_eq!(parsed.req("instances").as_arr().unwrap().len(), 1);
        let evs = parsed.req("op_events").as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].req("phase").as_str(), Some("completed"));
        assert_eq!(parsed.req("device_seconds").as_f64(), Some(10.0));
        assert_eq!(parsed.req("routes").as_usize(), Some(1));
        assert_eq!(parsed.req("reroutes").as_usize(), Some(0));
        let fev = parsed.req("fleet_events").as_arr().unwrap();
        assert_eq!(fev.len(), 1);
        assert_eq!(fev[0].req("phase").as_str(), Some("spin_up"));
    }

    #[test]
    fn forecast_block_is_strictly_additive() {
        let without = tiny_report().to_json().to_string();
        assert!(
            !without.contains("\"forecast\""),
            "no predictor → no forecast key: {without}"
        );
        let mut r = tiny_report();
        r.forecast = Some(crate::forecast::PredictReport {
            mae_ewma: 1.5,
            mae_holt: 1.0,
            mae_hw: 2.0,
            buckets: 30,
            stats: crate::forecast::PredictStats {
                proposed: 4,
                enacted: 2,
                vetoed: 1,
                drain_vetoes: 3,
            },
            oracle: false,
        });
        let with = r.to_json().to_string();
        let parsed = Json::parse(&with).unwrap();
        let f = parsed.req("forecast");
        assert_eq!(f.req("buckets").as_usize(), Some(30));
        assert_eq!(f.req("proposed").as_usize(), Some(4));
        assert_eq!(f.req("enacted").as_usize(), Some(2));
        assert_eq!(f.req("vetoed").as_usize(), Some(1));
        assert_eq!(f.req("drain_vetoes").as_usize(), Some(3));
        assert_eq!(f.req("mae_holt").as_f64(), Some(1.0));
        assert_eq!(f.req("oracle").as_f64(), Some(0.0));
        // everything else is unchanged
        let base = Json::parse(&without).unwrap();
        assert_eq!(base.req("completed"), parsed.req("completed"));
    }

    #[test]
    fn mempress_block_is_strictly_additive() {
        let without = tiny_report().to_json().to_string();
        assert!(
            !without.contains("\"mempress\""),
            "no governor → no mempress key: {without}"
        );
        let mut r = tiny_report();
        r.mempress = Some(crate::mempress::MempressReport {
            episodes: 9,
            kv_grows: 3,
            kv_shrinks: 1,
            pool_granted_bytes: 3e9,
            pool_reclaimed_bytes: 5e8,
            swap_requests: 2,
            swaps_applied: 8,
            swap_freed_bytes: 2.5e9,
            sheds_averted: 7,
            escalations: 2,
            quality_penalty: 0.64,
            quantized_layers: 8,
        });
        let with = r.to_json().to_string();
        let parsed = Json::parse(&with).unwrap();
        let m = parsed.req("mempress");
        assert_eq!(m.req("episodes").as_usize(), Some(9));
        assert_eq!(m.req("kv_grows").as_usize(), Some(3));
        assert_eq!(m.req("kv_shrinks").as_usize(), Some(1));
        assert_eq!(m.req("pool_granted_bytes").as_f64(), Some(3e9));
        assert_eq!(m.req("swap_requests").as_usize(), Some(2));
        assert_eq!(m.req("swaps_applied").as_usize(), Some(8));
        assert_eq!(m.req("swap_freed_bytes").as_f64(), Some(2.5e9));
        assert_eq!(m.req("sheds_averted").as_usize(), Some(7));
        assert_eq!(m.req("escalations").as_usize(), Some(2));
        assert_eq!(m.req("quality_penalty").as_f64(), Some(0.64));
        assert_eq!(m.req("quantized_layers").as_usize(), Some(8));
        // everything else is unchanged
        let base = Json::parse(&without).unwrap();
        assert_eq!(base.req("completed"), parsed.req("completed"));
    }

    #[test]
    fn audit_block_is_strictly_additive() {
        let without = tiny_report().to_json().to_string();
        assert!(
            !without.contains("\"audit\""),
            "no failure schedule → no audit key: {without}"
        );
        let mut r = tiny_report();
        let mut log = AuditLog::new();
        log.push(
            3.5,
            crate::coordinator::AuditKind::DeviceFailed,
            None,
            Some(1),
            "lost_bytes=42 holders=1",
        );
        log.push(
            3.5,
            crate::coordinator::AuditKind::RequestsShed,
            Some(0),
            None,
            "shed=2",
        );
        r.audit = Some(AuditBlock { log, unrouted_at_end: 1 });
        let with = r.to_json().to_string();
        let parsed = Json::parse(&with).unwrap();
        let a = parsed.req("audit");
        assert_eq!(a.req("unrouted_at_end").as_usize(), Some(1));
        let recs = a.req("records").as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].req("kind").as_str(), Some("device_failed"));
        assert_eq!(recs[0].req("device").as_usize(), Some(1));
        assert_eq!(recs[1].req("kind").as_str(), Some("requests_shed"));
        // two renders are byte-identical (replayable, diffable)
        assert_eq!(with, r.to_json().to_string());
        // everything else is unchanged
        let base = Json::parse(&without).unwrap();
        assert_eq!(base.req("completed"), parsed.req("completed"));
    }

    #[test]
    fn slo_block_is_strictly_additive() {
        let without = tiny_report().to_json().to_string();
        assert!(
            !without.contains("\"slo\":"),
            "classless policy → no slo key: {without}"
        );
        let mut r = tiny_report();
        r.slo = Some(SloBlock {
            premium_completed: 12,
            premium_slo_attainment: 0.75,
            be_completed: 34,
            be_slo_attainment: 0.5,
            preemptions: 3,
            premium_routes: 13,
            be_routes: 35,
        });
        let with = r.to_json().to_string();
        let parsed = Json::parse(&with).unwrap();
        let s = parsed.req("slo");
        assert_eq!(s.req("premium_completed").as_usize(), Some(12));
        assert_eq!(s.req("premium_slo_attainment").as_f64(), Some(0.75));
        assert_eq!(s.req("be_completed").as_usize(), Some(34));
        assert_eq!(s.req("be_slo_attainment").as_f64(), Some(0.5));
        assert_eq!(s.req("preemptions").as_usize(), Some(3));
        assert_eq!(s.req("premium_routes").as_usize(), Some(13));
        assert_eq!(s.req("be_routes").as_usize(), Some(35));
        // two renders are byte-identical
        assert_eq!(with, r.to_json().to_string());
        // everything else is unchanged
        let base = Json::parse(&without).unwrap();
        assert_eq!(base.req("completed"), parsed.req("completed"));
        assert_eq!(base.req("slo_attainment"), parsed.req("slo_attainment"));
    }

    #[test]
    fn timeline_is_strictly_additive() {
        let without = tiny_report().to_json().to_string();
        assert!(
            !without.contains("\"timeline\":"),
            "telemetry off → no timeline key: {without}"
        );
        let mut r = tiny_report();
        r.timeline = Some(crate::telemetry::TimelineBlock {
            window_s: 1.0,
            windows: vec![crate::telemetry::TimelineWindow {
                t_s: 1.0,
                arrivals: 3,
                completions: 2,
                sheds: 1,
                outstanding: 4,
                p50_s: 0.25,
                p99_s: 0.5,
                device_seconds: 8.0,
                busy_frac: 0.75,
            }],
        });
        let with = r.to_json().to_string();
        let parsed = Json::parse(&with).unwrap();
        let t = parsed.req("timeline");
        assert_eq!(t.req("window_s").as_f64(), Some(1.0));
        let ws = t.req("windows").as_arr().unwrap();
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].req("arrivals").as_usize(), Some(3));
        assert_eq!(ws[0].req("busy_frac").as_f64(), Some(0.75));
        // two renders are byte-identical
        assert_eq!(with, r.to_json().to_string());
        // everything else is unchanged
        let base = Json::parse(&without).unwrap();
        assert_eq!(base.req("completed"), parsed.req("completed"));
        // the span trace and kernel profile never reach the document
        let mut r = tiny_report();
        r.trace = Some(crate::telemetry::TraceBuffer {
            events: vec![],
            dropped: 0,
            n_instances: 0,
        });
        r.profile = Some(Default::default());
        assert_eq!(r.to_json().to_string(), without);
    }

    #[test]
    fn latency_p2_matches_exact_summary_on_small_samples() {
        let r = tiny_report();
        // a single completion: P² is exact below five samples
        assert_eq!(r.latency_p2(0.99), 2.5);
        assert_eq!(r.latency_p2(0.5), r.merged_latency().p50());
    }

    #[test]
    fn summary_math() {
        let r = tiny_report();
        assert_eq!(r.total_completed(), 1);
        assert!((r.merged_latency().mean() - 2.5).abs() < 1e-12);
        assert!((r.total_throughput_tps() - 2.0).abs() < 1e-12);
        assert_eq!(r.slo_attainment(), 1.0);
        assert_eq!(r.oom_rate(), 0.0);
    }
}
