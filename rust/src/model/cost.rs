//! The paper's §3.3 resource arithmetic — memory and FLOP costs per module.
//!
//! Reproduces **Table 1** exactly for LLaMA-13B under the paper's standard
//! inference conditions (batch 1, seq 256, bf16):
//!
//! | module                  | memory | computation  |
//! |-------------------------|--------|--------------|
//! | self_attn.q/k/v/o_proj  |  50 MB | 13.42 GFLOPs |
//! | self_attn               | 200 MB | 55.02 GFLOPs |
//! | ffn.gate/up/down_proj   | 135 MB | 36.24 GFLOPs |
//! | decoder layer           | 605 MB | 127.5 GFLOPs |
//!
//! Accounting notes (kept faithful to the paper, quirks included):
//! * "MB" is MiB (2^20) — 5120·5120·2 B = 50 MiB matches the paper's 50 MB.
//! * The decoder-layer FLOPs count attention + **two** FFN GEMMs
//!   (4·13.42 + 1.34 + 2·36.24 = 127.5) even though SwiGLU has three
//!   projections; the memory side counts all three (200 + 3·135 = 605).
//!   We follow the paper so Table 1 regenerates bit-for-bit; the simulator
//!   uses this same accounting for internal consistency.

use super::{ModelConfig, ModuleKind};

/// One mebibyte (2^20 bytes) — the paper's "MB" (see module docs).
pub const MIB: f64 = 1024.0 * 1024.0;
/// One gigaFLOP (1e9 floating-point operations).
pub const GFLOP: f64 = 1e9;

/// Element width of the bf16 baseline precision, in bytes.
pub const BF16_BYTES: usize = 2;
/// Element width of the int8 quantized precision, in bytes.
pub const INT8_BYTES: usize = 1;

/// Per-step quality penalty of serving ONE decoder layer at a precision
/// below bf16 (abstract quality-loss units, accumulated per decode step
/// per quantized layer and surfaced in the metrics JSON).
///
/// The value is the per-layer share of the ~0.02 perplexity-point
/// degradation runtime W8 quantization costs a 13B model (MorphServe §5,
/// arXiv 2506.02006), spread over the 40 layers: quantizing every layer
/// for an entire request costs about one full degradation unit. The
/// governor uses it to rank a swap against a shed — any nonzero penalty
/// is strictly cheaper than dropping a request.
pub const SWAP_QUALITY_PENALTY_PER_STEP: f64 = 0.02 / 40.0;

/// Inference-shape parameters the costs depend on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Shape {
    /// Concurrent sequences in the step.
    pub batch: usize,
    /// Tokens processed per sequence (1 for decode).
    pub seq: usize,
    /// Bytes per parameter/activation element (2 = bf16, 4 = f32).
    pub dtype_bytes: usize,
}

impl Shape {
    /// The paper's "standard inference conditions" (§3.3).
    pub fn paper_standard() -> Shape {
        Shape { batch: 1, seq: 256, dtype_bytes: 2 }
    }
}

/// Memory + compute cost of one module instance.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cost {
    /// Parameter bytes held in device memory.
    pub weight_bytes: f64,
    /// Floating-point operations per forward pass at the costed shape.
    pub flops: f64,
}

impl Cost {
    /// Memory footprint in MiB (Table 1's "MB" column).
    pub fn mem_mib(&self) -> f64 {
        self.weight_bytes / MIB
    }

    /// Compute in GFLOPs (Table 1's "computation" column).
    pub fn gflops(&self) -> f64 {
        self.flops / GFLOP
    }

    /// Compute density (GFLOPs per MiB) — the §3.3 classification signal.
    pub fn density(&self) -> f64 {
        if self.weight_bytes == 0.0 {
            f64::INFINITY
        } else {
            self.gflops() / self.mem_mib()
        }
    }
}

/// Cost model for a given architecture: the single place all byte/FLOP
/// arithmetic lives (simulator, autoscaler and benches all call this).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// The architecture all costs are derived from.
    pub cfg: ModelConfig,
}

impl CostModel {
    /// Build a cost model for `cfg`.
    pub fn new(cfg: ModelConfig) -> CostModel {
        CostModel { cfg }
    }

    /// Weight bytes of one module (KV cache handled by [`kv_cache_bytes`]).
    pub fn weight_bytes(&self, kind: ModuleKind, sh: Shape) -> f64 {
        let d = self.cfg.d_model as f64;
        let ff = self.cfg.d_ff as f64;
        let v = self.cfg.vocab_size as f64;
        let b = sh.dtype_bytes as f64;
        match kind {
            ModuleKind::QProj
            | ModuleKind::KProj
            | ModuleKind::VProj
            | ModuleKind::OProj => d * d * b,
            ModuleKind::Attn => 4.0 * d * d * b,
            ModuleKind::GateProj | ModuleKind::UpProj | ModuleKind::DownProj => {
                d * ff * b
            }
            ModuleKind::Ffn => 3.0 * d * ff * b,
            // attn + ffn + two RMSNorm vectors (the norms round to ~0 MB
            // at paper scale, matching Table 1's 605).
            ModuleKind::DecoderLayer => {
                (4.0 * d * d + 3.0 * d * ff + 2.0 * d) * b
            }
            ModuleKind::Embed => v * d * b,
            ModuleKind::LmHead => (v * d + d) * b,
            ModuleKind::KvCache => 0.0,
        }
    }

    /// Prefill-phase FLOPs of one module over `sh.batch`×`sh.seq` tokens,
    /// using the paper's accounting (see module docs).
    pub fn flops(&self, kind: ModuleKind, sh: Shape) -> f64 {
        let d = self.cfg.d_model as f64;
        let ff = self.cfg.d_ff as f64;
        let v = self.cfg.vocab_size as f64;
        let toks = (sh.batch * sh.seq) as f64;
        let seq = sh.seq as f64;
        let batch = sh.batch as f64;
        // Attention-score term: QK^T + PV = 2 · (2·seq²·d) FLOPs per
        // sequence = 1.34 GFLOPs at paper-standard shape (§3.3).
        let scores = 4.0 * seq * seq * d * batch;
        match kind {
            ModuleKind::QProj
            | ModuleKind::KProj
            | ModuleKind::VProj
            | ModuleKind::OProj => 2.0 * toks * d * d,
            ModuleKind::Attn => 4.0 * 2.0 * toks * d * d + scores,
            ModuleKind::GateProj | ModuleKind::UpProj | ModuleKind::DownProj => {
                2.0 * toks * d * ff
            }
            // Paper counts TWO ffn GEMMs in the layer total (127.5).
            ModuleKind::Ffn => 2.0 * (2.0 * toks * d * ff),
            ModuleKind::DecoderLayer => {
                self.flops(ModuleKind::Attn, sh) + self.flops(ModuleKind::Ffn, sh)
            }
            ModuleKind::Embed => 0.0, // gather, no MACs
            ModuleKind::LmHead => 2.0 * batch * d * v,
            ModuleKind::KvCache => 0.0,
        }
    }

    /// Memory + compute cost of one module at shape `sh`.
    pub fn cost(&self, kind: ModuleKind, sh: Shape) -> Cost {
        Cost { weight_bytes: self.weight_bytes(kind, sh), flops: self.flops(kind, sh) }
    }

    /// Decode-phase FLOPs for ONE new token per sequence, with `ctx` tokens
    /// already cached (attention reads the whole cache).
    pub fn decode_flops(&self, kind: ModuleKind, batch: usize, ctx: usize) -> f64 {
        let d = self.cfg.d_model as f64;
        let ff = self.cfg.d_ff as f64;
        let v = self.cfg.vocab_size as f64;
        let b = batch as f64;
        let ctx = ctx as f64 + 1.0;
        match kind {
            ModuleKind::QProj
            | ModuleKind::KProj
            | ModuleKind::VProj
            | ModuleKind::OProj => 2.0 * b * d * d,
            ModuleKind::Attn => 4.0 * 2.0 * b * d * d + 2.0 * b * ctx * d * 2.0,
            ModuleKind::GateProj | ModuleKind::UpProj | ModuleKind::DownProj => {
                2.0 * b * d * ff
            }
            ModuleKind::Ffn => 2.0 * (2.0 * b * d * ff),
            ModuleKind::DecoderLayer => {
                self.decode_flops(ModuleKind::Attn, batch, ctx as usize - 1)
                    + self.decode_flops(ModuleKind::Ffn, batch, 0)
            }
            ModuleKind::Embed => 0.0,
            ModuleKind::LmHead => 2.0 * b * d * v,
            ModuleKind::KvCache => 0.0,
        }
    }

    /// KV-cache bytes for one layer: 2 (K+V) · seq · d · dtype per sequence.
    pub fn kv_cache_bytes(&self, batch: usize, seq: usize, dtype_bytes: usize) -> f64 {
        2.0 * (batch * seq * self.cfg.d_model * dtype_bytes) as f64
    }

    /// Bytes *read* per decode step for one layer (weights + KV) — the
    /// memory-bound side of the decode roofline.
    pub fn decode_bytes_read(&self, batch: usize, ctx: usize, dtype_bytes: usize) -> f64 {
        self.weight_bytes(
            ModuleKind::DecoderLayer,
            Shape { batch, seq: 1, dtype_bytes },
        ) + self.kv_cache_bytes(batch, ctx, dtype_bytes)
    }

    /// Whole-model weight bytes (layers + embed + head).
    pub fn model_bytes(&self, dtype_bytes: usize) -> f64 {
        let sh = Shape { batch: 1, seq: 1, dtype_bytes };
        self.cfg.n_layers as f64 * self.weight_bytes(ModuleKind::DecoderLayer, sh)
            + self.weight_bytes(ModuleKind::Embed, sh)
            + self.weight_bytes(ModuleKind::LmHead, sh)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m13b() -> CostModel {
        CostModel::new(ModelConfig::llama2_13b())
    }

    /// Table 1 row 1: one attention projection = 50 MB, 13.42 GFLOPs.
    #[test]
    fn table1_projection() {
        let c = m13b().cost(ModuleKind::QProj, Shape::paper_standard());
        assert!((c.mem_mib() - 50.0).abs() < 0.01, "{}", c.mem_mib());
        assert!((c.gflops() - 13.42).abs() < 0.01, "{}", c.gflops());
    }

    /// Table 1 row 2: self_attn = 200 MB, 55.02 GFLOPs
    /// (4·13.42 GEMM + 1.34 attention scores).
    #[test]
    fn table1_self_attn() {
        let c = m13b().cost(ModuleKind::Attn, Shape::paper_standard());
        assert!((c.mem_mib() - 200.0).abs() < 0.01, "{}", c.mem_mib());
        assert!((c.gflops() - 55.02).abs() < 0.05, "{}", c.gflops());
    }

    /// Table 1 row 3: one FFN projection = 135 MB, 36.24 GFLOPs.
    #[test]
    fn table1_ffn_projection() {
        let c = m13b().cost(ModuleKind::GateProj, Shape::paper_standard());
        assert!((c.mem_mib() - 135.0).abs() < 0.01, "{}", c.mem_mib());
        assert!((c.gflops() - 36.24).abs() < 0.05, "{}", c.gflops());
    }

    /// Table 1 row 4: decoder layer = 605 MB, 127.5 GFLOPs.
    #[test]
    fn table1_decoder_layer() {
        let c = m13b().cost(ModuleKind::DecoderLayer, Shape::paper_standard());
        assert!((c.mem_mib() - 605.0).abs() < 0.05, "{}", c.mem_mib());
        assert!((c.gflops() - 127.5).abs() < 0.2, "{}", c.gflops());
    }

    /// §3.3 compute densities: ~0.275 GFLOPs/MB (attn), ~0.268 (FFN).
    #[test]
    fn densities_match_paper() {
        let m = m13b();
        let sh = Shape::paper_standard();
        let attn = m.cost(ModuleKind::Attn, sh).density();
        assert!((attn - 0.275).abs() < 0.003, "{attn}");
        let ffn_paperwise = 2.0 * 36.24 / (3.0 * 135.0); // paper's 0.268 uses 2-GEMM flops over 3-proj mem
        let ffn = m.cost(ModuleKind::Ffn, sh).density();
        assert!((ffn - ffn_paperwise).abs() < 0.003, "{ffn}");
    }

    /// §3.3: KV cache fluctuates "hundreds of MB to a few GB".
    #[test]
    fn kv_cache_magnitude() {
        let m = m13b();
        // one layer, batch 15, seq 256 (the paper's Fig. 4 batch): per-layer
        // KV; whole model = ×40 layers lands in the hundreds-of-MB..GB band.
        let one = m.kv_cache_bytes(15, 256, 2);
        let model_total = one * 40.0;
        assert!(model_total > 300.0 * MIB && model_total < 4096.0 * MIB,
                "{}", model_total / MIB);
    }

    #[test]
    fn decoder_layer_sums_parts() {
        let m = m13b();
        let sh = Shape::paper_standard();
        let attn = m.weight_bytes(ModuleKind::Attn, sh);
        let ffn = m.weight_bytes(ModuleKind::Ffn, sh);
        let layer = m.weight_bytes(ModuleKind::DecoderLayer, sh);
        assert!(layer >= attn + ffn);
        assert!(layer - (attn + ffn) < 0.1 * MIB); // + norms only
    }

    #[test]
    fn flops_scale_linearly_in_tokens() {
        let m = m13b();
        let s1 = Shape { batch: 1, seq: 128, dtype_bytes: 2 };
        let s2 = Shape { batch: 2, seq: 128, dtype_bytes: 2 };
        let f1 = m.flops(ModuleKind::QProj, s1);
        let f2 = m.flops(ModuleKind::QProj, s2);
        assert!((f2 / f1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_flops_much_smaller_than_prefill() {
        let m = m13b();
        let pre = m.flops(ModuleKind::DecoderLayer, Shape::paper_standard());
        let dec = m.decode_flops(ModuleKind::DecoderLayer, 1, 256);
        assert!(dec < pre / 100.0, "decode {dec} vs prefill {pre}");
    }

    #[test]
    fn model_bytes_13b_about_24gib() {
        // 40 layers · 605 MiB + embed/head ≈ 24.2 GiB in bf16.
        let gib = m13b().model_bytes(2) / (1024.0 * MIB);
        assert!((23.0..26.0).contains(&gib), "{gib}");
    }

    /// Weight bytes are linear in dtype width, so an int8 swap halves the
    /// layer's memory footprint and its roofline weight-read term — the
    /// mechanism behind `ModuleOp::SwapPrecision`.
    #[test]
    fn int8_swap_halves_layer_weight_bytes() {
        let m = m13b();
        let bf16 = Shape { batch: 1, seq: 1, dtype_bytes: BF16_BYTES };
        let int8 = Shape { batch: 1, seq: 1, dtype_bytes: INT8_BYTES };
        let w2 = m.weight_bytes(ModuleKind::DecoderLayer, bf16);
        let w1 = m.weight_bytes(ModuleKind::DecoderLayer, int8);
        assert!((2.0 * w1 - w2).abs() < 1e-6, "{w1} vs {w2}");
        // a fully-quantized model over one request ~ one degradation unit
        let per_request =
            SWAP_QUALITY_PENALTY_PER_STEP * ModelConfig::llama2_13b().n_layers as f64;
        assert!((per_request - 0.02).abs() < 1e-12);
    }

    #[test]
    fn decode_is_memory_bound_on_a100_arithmetic() {
        // FLOPs/byte of a decode step at batch 1 must sit far below the
        // A100's ~200 FLOP/byte ridge point — the §2.1 claim.
        let m = m13b();
        let f = m.decode_flops(ModuleKind::DecoderLayer, 1, 256);
        let by = m.decode_bytes_read(1, 256, 2);
        assert!(f / by < 8.0, "intensity {}", f / by);
    }
}
