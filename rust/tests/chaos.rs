//! Deterministic chaos harness: seeded device failures injected into the
//! event kernel across the full scenario library, asserting the
//! failure-domain conservation invariants end to end.
//!
//! * **Seed determinism** — the failure schedule is part of the seeded
//!   initial conditions: two runs with the same trace seed and the same
//!   schedule produce byte-identical metrics JSON *including* the audit
//!   trail, for every scenario shape.
//! * **Request conservation** — every trace arrival is either completed
//!   exactly once or still parked in the router at the drain deadline
//!   (`audit.unrouted_at_end`); failures shed and re-route requests but
//!   never lose or duplicate one.
//! * **Billing stops at the failure instant** — the cost ledger bills no
//!   device-seconds for a dead device past its failure time.
//! * **Tag hygiene** — a force-released instance leaves no `inst{id}/`
//!   ledger bytes on surviving devices (observable as the survivors'
//!   end-of-run memory fractions).
//! * **Goldens unchanged** — an empty schedule adds no `DeviceFailed`
//!   events and no `audit` key: byte-identical to a run that never heard
//!   of failures.

use std::collections::BTreeSet;

use cocoserve::baselines;
use cocoserve::cluster::{Cluster, DeviceSpec, GIB};
use cocoserve::coordinator::{CostLedger, FleetConfig, RoutePolicy, RouterConfig};
use cocoserve::model::cost::CostModel;
use cocoserve::model::{ModelConfig, ModuleKind};
use cocoserve::ops::ModuleOps;
use cocoserve::placement::Placement;
use cocoserve::sim::{FleetSetup, SimConfig, SimReport, Simulation};
use cocoserve::workload::{FailureSchedule, Trace};

/// Elastic 2-instance fleet on five devices; instance 0 lives on device 0,
/// which no chaos schedule in this file ever kills — so at least one
/// server always survives and every run drains fully.
fn chaos_fleet(trace: &Trace, duration_s: f64, schedule: FailureSchedule) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::homogeneous(5, DeviceSpec::a100_40gb());
    let policy = baselines::cocoserve(32);
    let placements: Vec<_> = (0..2)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
        .collect();
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::LeastOutstanding,
            admission_limit: Some(64),
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(FleetConfig::elastic(2, 5, policy)),
        ..Default::default()
    };
    Simulation::with_fleet(cfg, cluster, placements, setup)
        .with_failures(schedule)
        .run(trace, duration_s)
}

/// Unique completed request ids across every monitor; panics on a
/// duplicate (a request that completed twice breaks conservation).
fn completed_ids(r: &SimReport) -> BTreeSet<u64> {
    let mut seen = BTreeSet::new();
    for m in &r.monitors {
        for c in m.completions() {
            assert!(
                seen.insert(c.request_id),
                "request {} completed more than once",
                c.request_id
            );
        }
    }
    seen
}

/// `completed + parked-at-deadline == trace length`: every arrival is
/// accounted for exactly once no matter what died mid-run.
fn assert_conservation(r: &SimReport, trace: &Trace, label: &str) {
    let ids = completed_ids(r);
    assert_eq!(ids.len(), r.total_completed(), "{label}: monitor id sets disagree");
    let unrouted = r
        .audit
        .as_ref()
        .expect("chaos runs carry an audit block")
        .unrouted_at_end;
    assert_eq!(
        r.total_completed() + unrouted,
        trace.len(),
        "{label}: {} completed + {} unrouted != {} arrivals",
        r.total_completed(),
        unrouted,
        trace.len()
    );
}

#[test]
fn same_seed_chaos_runs_are_byte_identical_across_scenarios() {
    for (name, trace) in Trace::scenario_sweep(14.0, 12.0, 63) {
        // devices 1 and 3 die mid-run; device 0 (and instance 0) survive
        let schedule = FailureSchedule::seeded(&[1, 3], 12.0, 2, 63);
        assert_eq!(schedule.len(), 2);
        let a = chaos_fleet(&trace, 12.0, schedule.clone());
        let b = chaos_fleet(&trace, 12.0, schedule.clone());
        let aj = a.to_json().to_string();
        let bj = b.to_json().to_string();
        assert_eq!(aj, bj, "chaos scenario `{name}` not replay-deterministic");
        assert!(
            aj.contains("\"audit\""),
            "chaos scenario `{name}` must carry the audit trail"
        );
        let audit = a.audit.as_ref().expect("audit block");
        let failures = audit
            .log
            .records()
            .iter()
            .filter(|rec| rec.kind.name() == "device_failed")
            .count();
        assert_eq!(failures, 2, "`{name}`: one audit record per scheduled death");
        assert_conservation(&a, &trace, name);
        assert!(a.total_completed() > 0, "chaos scenario `{name}` served nothing");
    }
}

#[test]
fn sharded_chaos_kernel_matches_sequential_byte_for_byte() {
    let trace = Trace::burst(16.0, 12.0, 11);
    let schedule = FailureSchedule::seeded(&[1, 3], 12.0, 2, 11);
    let run = |shards: usize| {
        let mut cfg = SimConfig::paper_13b();
        cfg.shards = shards;
        let cluster = Cluster::homogeneous(5, DeviceSpec::a100_40gb());
        let policy = baselines::cocoserve(32);
        let placements: Vec<_> = (0..2)
            .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
            .collect();
        let setup = FleetSetup {
            router: RouterConfig {
                policy: RoutePolicy::LeastOutstanding,
                admission_limit: Some(64),
                reroute_on_shed: true,
                ..RouterConfig::default()
            },
            fleet: Some(FleetConfig::elastic(2, 5, policy)),
            ..Default::default()
        };
        Simulation::with_fleet(cfg, cluster, placements, setup)
            .with_failures(schedule.clone())
            .run(&trace, 12.0)
            .to_json()
            .to_string()
    };
    assert_eq!(run(1), run(2), "DeviceFailed must be an exact barrier event");
}

#[test]
fn empty_schedule_leaves_goldens_byte_identical() {
    let trace = Trace::steady(12.0, 10.0, 41);
    let run = |with_builder: bool| {
        let cfg = SimConfig::paper_13b();
        let cluster = Cluster::homogeneous(3, DeviceSpec::a100_40gb());
        let placements: Vec<_> = (0..2)
            .map(|i| {
                (
                    Placement::single_device(cfg.model.n_layers, i),
                    baselines::vllm_like(16),
                )
            })
            .collect();
        let sim = Simulation::new(cfg, cluster, placements);
        let sim = if with_builder {
            sim.with_failures(FailureSchedule::default())
        } else {
            sim
        };
        sim.run(&trace, 10.0)
    };
    let plain = run(false);
    let built = run(true);
    assert!(plain.audit.is_none() && built.audit.is_none());
    let pj = plain.to_json().to_string();
    assert_eq!(pj, built.to_json().to_string(), "empty schedule must be a no-op");
    assert!(!pj.contains("\"audit\""), "no failures → no audit key");
}

#[test]
fn lost_instance_frees_survivor_tags_and_stops_billing() {
    // Instance 1 lives on device 1 except for its upper 5 layers, which
    // are placed on device 2. Device 2 is then hogged to the brim and
    // device 0 serves instance 0 — so when device 1 dies at t=4 the
    // emergency migration of instance 1's 35 sole-copy lower layers
    // (~21 GB) cannot fit in device 0's ≤ 13.5 GB slack and device 2's
    // half-layer, and the instance is force-released. The contracts
    // under test:
    //   * its requests re-route to instance 0 — conservation holds;
    //   * every `inst1/` tag on the *surviving* device 2 is freed —
    //     device 2 ends at exactly the hog bytes;
    //   * the dead device bills no device-seconds past t=4.
    let cfg = SimConfig::paper_13b();
    let n_layers = cfg.model.n_layers;
    let cm = CostModel::new(ModelConfig::llama2_13b());
    let ops = ModuleOps::new(&cm, cfg.dtype_bytes, "probe");
    let layer_bytes = ops.module_bytes(ModuleKind::DecoderLayer);

    let mut cluster = Cluster::homogeneous(3, DeviceSpec::a100_40gb());
    // fill device 2 down to half a layer of slack, leaving room for the
    // 5 upper layers instance 1 will deploy there
    let upper_bytes = 5.0 * layer_bytes;
    let hog2 = cluster.device(2).free_bytes() - upper_bytes - 0.5 * layer_bytes;
    cluster.device_mut(2).alloc("hog", hog2).unwrap();

    let mut pl1 = Placement::single_device(n_layers, 1);
    for l in (n_layers - 5)..n_layers {
        pl1.migrate_layer(l, 2);
    }
    let placements = vec![
        (Placement::single_device(n_layers, 0), baselines::vllm_like(16)),
        (pl1, baselines::vllm_like(16)),
    ];
    let duration = 12.0;
    let trace = Trace::steady(8.0, duration, 23);
    let r = Simulation::new(cfg, cluster, placements)
        .with_failures(FailureSchedule::at(&[(4.0, 1)]))
        .run(&trace, duration);

    assert_conservation(&r, &trace, "lost-instance");
    assert_eq!(
        r.audit.as_ref().unwrap().unrouted_at_end,
        0,
        "instance 0 survives, so everything must drain"
    );
    assert_eq!(r.total_completed(), trace.len());

    let kinds: Vec<&str> = r
        .audit
        .as_ref()
        .unwrap()
        .log
        .records()
        .iter()
        .map(|rec| rec.kind.name())
        .collect();
    assert!(kinds.contains(&"device_failed"));
    assert!(kinds.contains(&"forced_release"), "audit: {kinds:?}");
    assert!(kinds.contains(&"instance_lost"), "audit: {kinds:?}");

    // survivor tag hygiene: device 2 ends at exactly the hog bytes —
    // instance 1's 5 upper layers (and any partial emergency copies)
    // were freed wholesale by the forced release
    let spec_bytes = 40.0 * GIB;
    let (_, _, mem2) = r.device_util[2];
    assert!(
        (mem2 - hog2 / spec_bytes).abs() < 1e-12,
        "inst1 tags leaked on surviving device 2: frac {mem2} vs hog {}",
        hog2 / spec_bytes
    );
    // the dead device reads as full (free_bytes == 0 marker)
    let (_, _, mem1) = r.device_util[1];
    assert_eq!(mem1, 1.0, "failed device must refuse all future work");

    // billing: device 0 bills the whole run; devices 1 and 2 (instance
    // 1's device set) bill only until the forced release at t=4
    assert!(
        r.device_seconds <= r.duration_s + 2.0 * 4.0 + 1e-6,
        "lost instance billed past its failure: {} > {} + 8",
        r.device_seconds,
        r.duration_s
    );
    assert!(r.device_seconds >= r.duration_s - 1e-6);
}

#[test]
fn cost_ledger_stops_billing_at_the_failure_instant() {
    let mut ledger = CostLedger::new(2);
    ledger.acquire(0);
    ledger.acquire(1);
    ledger.advance(10.0);
    assert!((ledger.device_seconds() - 20.0).abs() < 1e-12);
    assert_eq!(ledger.fail_device(1), 1, "one holder zeroed at failure");
    ledger.advance(25.0);
    assert!(
        (ledger.device_seconds() - 35.0).abs() < 1e-12,
        "only the survivor may bill past the failure: {}",
        ledger.device_seconds()
    );
    // idempotent: a dead device has no holders left to zero
    assert_eq!(ledger.fail_device(1), 0);
}

#[test]
fn heterogeneous_spot_fleet_survives_seeded_preemptions() {
    // Mixed generations with spot capacity: the preemptible devices are
    // exactly the chaos targets. Seed-deterministic, byte-replayable,
    // and conservation holds on the survivors.
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::mixed(vec![
        DeviceSpec::a100_40gb(),
        DeviceSpec::h100_80gb(),
        DeviceSpec::a100_40gb().spot(),
        DeviceSpec::v100_32gb().spot(),
    ]);
    let targets = cluster.preemptible_devices();
    assert_eq!(targets, vec![2, 3]);
    let duration = 12.0;
    let schedule = FailureSchedule::seeded(&targets, duration, 2, 7);
    let policy = baselines::cocoserve(32);
    let placements: Vec<_> = (0..2)
        .map(|i| (Placement::single_device(cfg.model.n_layers, i), policy))
        .collect();
    let setup = FleetSetup {
        router: RouterConfig {
            policy: RoutePolicy::KvHeadroom,
            admission_limit: Some(64),
            reroute_on_shed: true,
            ..RouterConfig::default()
        },
        fleet: Some(FleetConfig::elastic(2, 4, policy)),
        ..Default::default()
    };
    let trace = Trace::burst(14.0, duration, 19);
    let run = || {
        Simulation::with_fleet(
            cfg.clone(),
            cluster.clone(),
            placements.clone(),
            setup,
        )
        .with_failures(schedule.clone())
        .run(&trace, duration)
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "mixed-fleet chaos must replay byte-identically"
    );
    assert_conservation(&a, &trace, "heterogeneous-spot");
    assert!(a.total_completed() > 0);
}

#[test]
fn chaos_grid_holds_conservation_at_every_failure_time() {
    // Sweep the failure instant across the run — including times that can
    // land while the victim instance is `Draining` (elastic scale-in
    // after the early burst) — and assert the conservation invariants at
    // every grid point. This is the regression net for the
    // preempted-while-draining path: whatever lifecycle state the death
    // interrupts, no request is lost or double-completed and the
    // schedule stays byte-replayable.
    let duration = 14.0;
    let trace = Trace::burst(16.0, duration, 83);
    for k in 0..6 {
        let t = 3.0 + 2.0 * k as f64; // 3, 5, 7, 9, 11, 13
        let schedule = FailureSchedule::at(&[(t, 1)]);
        let a = chaos_fleet(&trace, duration, schedule.clone());
        let b = chaos_fleet(&trace, duration, schedule);
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "failure at t={t} not replay-deterministic"
        );
        assert_conservation(&a, &trace, &format!("grid t={t}"));
    }
}
