//! §8 — interference of scaling operations on neighbouring instances.
//!
//! Paper claims: during dynamic module scaling, adjacent instances see
//! <3% throughput fluctuation and <5% latency jitter. Setup: two
//! instances on separate devices of the paper testbed; instance 0
//! performs scaling ops mid-run; instance 1 (the neighbour, never
//! scaling) is compared against a control run where instance 0 never
//! scales either. Both cells run through the deterministic event kernel
//! under the golden-replay discipline:
//!
//! (a) both claims asserted in-process (not just printed);
//! (b) the scaling cell demonstrably scaled — the control cell records
//!     no module ops, the scaling cell records at least one;
//! (c) each cell golden-replays byte-identically, full metrics JSON.
//!
//! ```bash
//! cargo bench --bench interference
//! GOLDEN_OUT=interference.json cargo bench --bench interference
//! ```
//!
//! `GOLDEN_OUT=<path>` writes both cells' metrics JSON for byte-diffing
//! across runs.

use cocoserve::baselines;
use cocoserve::cluster::Cluster;
use cocoserve::placement::Placement;
use cocoserve::sim::{SimConfig, SimReport, Simulation};
use cocoserve::util::bench::{Report, Table};
use cocoserve::util::json;
use cocoserve::workload::{Arrival, LengthDist, Trace};

const RPS: f64 = 25.0;
const DURATION_S: f64 = 25.0;
const SEED: u64 = 31;

fn run(scaling: bool, trace: &Trace) -> SimReport {
    let cfg = SimConfig::paper_13b();
    let cluster = Cluster::paper_testbed();
    let p0 = Placement::single_device(cfg.model.n_layers, 0);
    let p1 = Placement::single_device(cfg.model.n_layers, 1);
    let inst0 = if scaling {
        baselines::cocoserve(64) // scales during the run
    } else {
        baselines::cocoserve_no_autoscale(64)
    };
    Simulation::new(
        cfg,
        cluster,
        vec![(p0, inst0), (p1, baselines::cocoserve_no_autoscale(64))],
    )
    .run(trace, DURATION_S)
}

/// Neighbour metrics: instance 1's throughput and mean latency.
fn neighbour(r: &SimReport) -> (f64, f64) {
    let m = &r.monitors[1];
    (m.throughput_tokens_per_s(r.duration_s), m.latency_summary().mean())
}

fn main() {
    println!("§8 — scaling interference on a neighbouring instance ({RPS:.0} RPS)\n");
    let golden_out = std::env::var("GOLDEN_OUT").ok().filter(|p| !p.is_empty());
    let trace =
        Trace::generate(Arrival::Poisson { rps: RPS }, LengthDist::alpaca(), DURATION_S, SEED);

    // (c) golden replay per cell
    let mut replay_ok = true;
    let mut dump = String::new();
    let mut cell = |scaling: bool, name: &str| -> SimReport {
        let r = run(scaling, &trace);
        let again = run(scaling, &trace);
        let rj = r.to_json().to_string();
        let identical = rj == again.to_json().to_string();
        replay_ok &= identical;
        if !identical {
            eprintln!("WARNING: cell `{name}` not replay-deterministic");
        }
        if golden_out.is_some() {
            dump.push_str(name);
            dump.push('\n');
            dump.push_str(&rj);
            dump.push('\n');
        }
        r
    };
    let base = cell(false, "control");
    let scaled = cell(true, "scaling");

    // (b) the experiment is non-vacuous: the control never scales, the
    // scaling cell records module ops
    assert!(base.op_events.is_empty(), "control cell must record no module ops");
    assert!(
        !scaled.op_events.is_empty(),
        "scaling cell recorded no module ops — instance 0 never scaled"
    );
    assert!(
        !base.monitors[1].completions().is_empty(),
        "the neighbour served nothing — the trace never reached instance 1"
    );

    let (thr_base, lat_base) = neighbour(&base);
    let (thr_scaled, lat_scaled) = neighbour(&scaled);
    let thr_fluct = (thr_scaled - thr_base).abs() / thr_base * 100.0;
    let lat_jitter = (lat_scaled - lat_base).abs() / lat_base * 100.0;

    let mut t = Table::new(&["neighbour metric", "no scaling", "with scaling", "delta"]);
    t.row(&[
        "throughput (tok/s)".into(),
        format!("{thr_base:.1}"),
        format!("{thr_scaled:.1}"),
        format!("{thr_fluct:.2}%"),
    ]);
    t.row(&[
        "mean latency (s)".into(),
        format!("{lat_base:.3}"),
        format!("{lat_scaled:.3}"),
        format!("{lat_jitter:.2}%"),
    ]);
    t.print();
    println!(
        "\npaper: throughput fluctuation <3%, latency jitter <5% — measured \
         {thr_fluct:.2}% / {lat_jitter:.2}%"
    );
    println!(
        "golden replay across both cells: {}",
        if replay_ok { "byte-identical ✓" } else { "MISMATCH ✗" }
    );

    let mut rep = Report::new("interference");
    rep.set("throughput_fluct_pct", json::num(thr_fluct));
    rep.set("latency_jitter_pct", json::num(lat_jitter));
    rep.set("scaling_ops", json::num(scaled.op_events.len() as f64));
    rep.set("replay_ok", json::num(f64::from(u8::from(replay_ok))));
    println!("report: {}", rep.write().unwrap().display());
    if let Some(path) = &golden_out {
        std::fs::write(path, dump).expect("write GOLDEN_OUT");
        println!("golden metrics: {path}");
    }

    // (a) the paper's interference bounds, asserted
    assert!(
        thr_fluct < 3.0,
        "neighbour throughput fluctuation {thr_fluct:.2}% breaches the <3% claim"
    );
    assert!(
        lat_jitter < 5.0,
        "neighbour latency jitter {lat_jitter:.2}% breaches the <5% claim"
    );
    assert!(replay_ok, "metrics JSON must be identical across same-seed runs");
}
