"""Pure-jnp reference implementations ("oracle") for every kernel and module.

These are the correctness ground truth: the Pallas kernels
(`flash_attention.py`, `fused_rmsnorm_matmul.py`) and the composed module
functions (`model.py`) are asserted allclose against these in
`python/tests/`. Keep them boring and obviously-correct — no tiling, no
fusion, no tricks.
"""

import jax.numpy as jnp


def rmsnorm(x, weight, eps: float = 1e-6):
    """RMSNorm over the last axis: x / rms(x) * weight."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * weight


def rope(x, positions):
    """Rotary position embedding.

    x: [batch, heads, seq, head_dim]; positions: [batch, seq] (int32).
    Standard LLaMA theta=10000 formulation over half the head dim.
    """
    b, h, s, hd = x.shape
    half = hd // 2
    freq = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    # [batch, seq, half]
    angles = positions[:, :, None].astype(jnp.float32) * freq[None, None, :]
    cos = jnp.cos(angles)[:, None, :, :]  # [b, 1, s, half]
    sin = jnp.sin(angles)[:, None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention(q, k, v, mask=None, scale=None):
    """Plain softmax attention.

    q: [b, h, sq, hd], k/v: [b, h, sk, hd].
    mask: broadcastable to [b, h, sq, sk]; True = attend.
    """
    hd = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def causal_mask(sq: int, sk: int):
    """Causal mask for a prefill block where queries are the last sq of sk."""
    qi = jnp.arange(sq)[:, None] + (sk - sq)
    ki = jnp.arange(sk)[None, :]
    return ki <= qi  # [sq, sk]


def swiglu_ffn(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward: down( silu(x@gate) * (x@up) )."""
    g = x @ w_gate
    u = x @ w_up
    silu = g * (1.0 / (1.0 + jnp.exp(-g)))
    return (silu * u) @ w_down


def rmsnorm_matmul(x, weight, w):
    """Fused RMSNorm followed by a matmul — oracle for the Pallas kernel."""
    return rmsnorm(x, weight) @ w


def decoder_layer_prefill(hidden, positions, weights):
    """Full decoder layer over a prompt chunk.

    hidden: [b, s, d]; positions: [b, s] int32 absolute positions.
    weights: dict with rms1, wq, wk, wv, wo, rms2, w_gate, w_up, w_down,
    n_heads. Returns (hidden_out [b,s,d], k [b,h,s,hd], v [b,h,s,hd]).
    """
    b, s, d = hidden.shape
    n_heads = weights["n_heads"]
    hd = d // n_heads

    x = rmsnorm(hidden, weights["rms1"])
    q = (x @ weights["wq"]).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ weights["wk"]).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ weights["wv"]).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    q = rope(q, positions)
    k = rope(k, positions)
    mask = causal_mask(s, s)[None, None, :, :]
    attn = attention(q, k, v, mask)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, d)
    hidden = hidden + attn @ weights["wo"]

    x = rmsnorm(hidden, weights["rms2"])
    hidden = hidden + swiglu_ffn(x, weights["w_gate"], weights["w_up"], weights["w_down"])
    return hidden, k, v


def decoder_layer_decode(hidden, k_cache, v_cache, seq_lens, weights):
    """Single decode step with a static-capacity KV cache.

    hidden: [b, 1, d]; k_cache/v_cache: [b, h, S, hd]; seq_lens: [b] int32 —
    number of tokens already cached per sequence (the new token lands at
    index seq_lens[i]). Returns (hidden_out, k_cache', v_cache').
    """
    b, _, d = hidden.shape
    n_heads = weights["n_heads"]
    hd = d // n_heads
    S = k_cache.shape[2]

    x = rmsnorm(hidden, weights["rms1"])
    q = (x @ weights["wq"]).reshape(b, 1, n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ weights["wk"]).reshape(b, 1, n_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ weights["wv"]).reshape(b, 1, n_heads, hd).transpose(0, 2, 1, 3)
    pos = seq_lens[:, None]  # [b, 1]
    q = rope(q, pos)
    k = rope(k, pos)

    # Scatter the new K/V into the cache at per-sequence positions.
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, :, seq_lens, :].set(k[:, :, 0, :])
    v_cache = v_cache.at[bidx, :, seq_lens, :].set(v[:, :, 0, :])

    # Attend over valid cache slots only (idx <= seq_lens).
    idx = jnp.arange(S)[None, None, None, :]  # [1,1,1,S]
    mask = idx <= seq_lens[:, None, None, None]
    attn = attention(q, k_cache, v_cache, mask)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, 1, d)
    hidden = hidden + attn @ weights["wo"]

    x = rmsnorm(hidden, weights["rms2"])
    hidden = hidden + swiglu_ffn(x, weights["w_gate"], weights["w_up"], weights["w_down"])
    return hidden, k_cache, v_cache
