//! Streaming traffic estimators — the O(1)-memory signal path of the
//! predictive control plane.
//!
//! Every estimator consumes the arrival stream as a sequence of
//! fixed-width *rate buckets* (arrivals per second over `bucket_s`
//! windows) and is pure `f64` arithmetic over that sequence: no
//! allocation on the update path (the Holt-Winters seasonal table and the
//! oracle rate table are allocated once, at construction / configuration
//! time), no clocks, no randomness. Two runs over the same arrival
//! sequence therefore produce bit-identical forecasts — the property the
//! fleet golden-replay suite depends on, and the reason the
//! `fleet_scale` bench's counting-allocator probe can assert the
//! observe/forecast path allocation-free.
//!
//! Four estimators cover the scenario library's shapes:
//!
//! * [`Ewma`] — exponentially-weighted rate (steady traffic),
//! * [`Holt`] — double exponential smoothing, level + trend (ramps),
//! * [`HoltWinters`] — additive seasonality over a configurable period
//!   (diurnal cycles),
//! * [`BurstDetector`] — a z-score detector over exponentially-weighted
//!   mean/variance (flash crowds the smoothers are too slow for).
//!
//! [`TrafficForecaster`] composes them behind one `observe`/`forecast`
//! interface, tracks each estimator's one-bucket-ahead mean absolute
//! error (the `forecast` block of the simulator's metrics JSON), and can
//! be switched into *oracle* mode — forecasts read from a precomputed
//! table of the trace's true future rates — for upper-bound benching.

/// Exponentially-weighted moving average of the bucket rate.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: f64,
    primed: bool,
}

impl Ewma {
    /// An EWMA with smoothing factor `alpha` ∈ (0, 1].
    pub fn new(alpha: f64) -> Ewma {
        Ewma { alpha, value: 0.0, primed: false }
    }

    /// Fold one closed bucket's rate into the average.
    pub fn update(&mut self, rate: f64) {
        if self.primed {
            self.value += self.alpha * (rate - self.value);
        } else {
            self.value = rate;
            self.primed = true;
        }
    }

    /// Current rate estimate (also the EWMA's forecast at any horizon).
    pub fn value(&self) -> f64 {
        self.value
    }
}

/// Holt double exponential smoothing: level + trend, the ramp tracker.
#[derive(Debug, Clone)]
pub struct Holt {
    alpha: f64,
    beta: f64,
    level: f64,
    trend: f64,
    primed: bool,
}

impl Holt {
    /// Holt smoothing with level factor `alpha` and trend factor `beta`.
    pub fn new(alpha: f64, beta: f64) -> Holt {
        Holt { alpha, beta, level: 0.0, trend: 0.0, primed: false }
    }

    /// Fold one closed bucket's rate into level and trend.
    pub fn update(&mut self, rate: f64) {
        if !self.primed {
            self.level = rate;
            self.trend = 0.0;
            self.primed = true;
            return;
        }
        let prev_level = self.level;
        self.level = self.alpha * rate + (1.0 - self.alpha) * (self.level + self.trend);
        self.trend = self.beta * (self.level - prev_level) + (1.0 - self.beta) * self.trend;
    }

    /// Forecast `k` buckets ahead: level + k·trend (callers clamp to ≥ 0 —
    /// a downtrend extrapolates below zero).
    pub fn forecast(&self, k: f64) -> f64 {
        self.level + k * self.trend
    }

    /// Current level estimate.
    pub fn level(&self) -> f64 {
        self.level
    }

    /// Current per-bucket trend estimate.
    pub fn trend(&self) -> f64 {
        self.trend
    }
}

/// Additive Holt-Winters triple exponential smoothing: level + trend +
/// a seasonal table of `period` buckets (the diurnal tracker). Until one
/// full period of data has been seen the seasonal terms are zero and the
/// estimator behaves exactly like [`Holt`].
#[derive(Debug, Clone)]
pub struct HoltWinters {
    alpha: f64,
    beta: f64,
    gamma: f64,
    level: f64,
    trend: f64,
    /// Per-phase additive seasonal offsets — allocated once here, indexed
    /// (never grown) on the update path.
    season: Vec<f64>,
    /// Phase of the *next* bucket to fold (0..period).
    idx: usize,
    buckets_seen: u64,
    primed: bool,
}

impl HoltWinters {
    /// Holt-Winters with the given smoothing factors and seasonal
    /// `period` (in buckets; ≥ 1 — a period of 1 degenerates to Holt).
    pub fn new(alpha: f64, beta: f64, gamma: f64, period: usize) -> HoltWinters {
        HoltWinters {
            alpha,
            beta,
            gamma,
            level: 0.0,
            trend: 0.0,
            season: vec![0.0; period.max(1)],
            idx: 0,
            buckets_seen: 0,
            primed: false,
        }
    }

    /// Seasonal period in buckets.
    pub fn period(&self) -> usize {
        self.season.len()
    }

    /// Fold one closed bucket's rate into level, trend, and the bucket's
    /// seasonal phase.
    pub fn update(&mut self, rate: f64) {
        let p = self.season.len();
        if !self.primed {
            self.level = rate;
            self.trend = 0.0;
            self.primed = true;
            self.idx = 1 % p;
            self.buckets_seen = 1;
            return;
        }
        let i = self.idx;
        let prev_level = self.level;
        let deseason = rate - self.season[i];
        self.level = self.alpha * deseason + (1.0 - self.alpha) * (self.level + self.trend);
        self.trend = self.beta * (self.level - prev_level) + (1.0 - self.beta) * self.trend;
        self.season[i] = self.gamma * (rate - self.level) + (1.0 - self.gamma) * self.season[i];
        self.idx = (i + 1) % p;
        self.buckets_seen += 1;
    }

    /// Forecast `k` buckets ahead: level + k·trend + the seasonal offset
    /// of the target phase (zero until a full period has been seen).
    ///
    /// The last folded bucket sat at phase `idx - 1` (mod p), so the
    /// bucket `k` ahead of it sits at phase `idx - 1 + k` (mod p) —
    /// `k = 0` is the bucket just closed, `k = 1` the next one (phase
    /// `idx`). The old `k.saturating_sub(1)` derivation made horizons 0
    /// and 1 silently read the same seasonal slot.
    pub fn forecast(&self, k: usize) -> f64 {
        let p = self.season.len();
        let seasonal = if self.buckets_seen as usize >= p {
            self.season[(self.idx + p - 1 + k) % p]
        } else {
            0.0
        };
        self.level + k as f64 * self.trend + seasonal
    }
}

/// Variance-ratio burst detector: flags a bucket whose rate sits more
/// than `sigma` standard deviations above the long-run exponentially-
/// weighted mean. The smoothers above deliberately lag (that is what
/// makes them stable); this is the fast path that lets the predictive
/// controller react to a flash crowd within one bucket.
#[derive(Debug, Clone)]
pub struct BurstDetector {
    alpha: f64,
    sigma: f64,
    mean: f64,
    var: f64,
    last_z: f64,
    primed_buckets: u64,
}

impl BurstDetector {
    /// A detector with long-run smoothing factor `alpha` (small = long
    /// memory) firing above `sigma` standard deviations.
    pub fn new(alpha: f64, sigma: f64) -> BurstDetector {
        BurstDetector { alpha, sigma, mean: 0.0, var: 0.0, last_z: 0.0, primed_buckets: 0 }
    }

    /// Score one closed bucket against the long-run statistics, then fold
    /// it in (the bucket never scores against itself).
    pub fn update(&mut self, rate: f64) {
        // require a few buckets of history before scoring — the first
        // observations define the baseline, they cannot deviate from it
        if self.primed_buckets >= 3 {
            let std = self.var.max(1e-12).sqrt();
            self.last_z = (rate - self.mean) / std;
        } else {
            self.last_z = 0.0;
        }
        if self.primed_buckets == 0 {
            self.mean = rate;
            self.var = 0.0;
        } else {
            let d = rate - self.mean;
            let incr = self.alpha * d;
            self.mean += incr;
            self.var = (1.0 - self.alpha) * (self.var + d * incr);
        }
        self.primed_buckets += 1;
    }

    /// Did the most recent bucket score as a burst?
    pub fn is_burst(&self) -> bool {
        self.last_z > self.sigma
    }

    /// z-score of the most recent bucket against the long-run statistics.
    pub fn last_z(&self) -> f64 {
        self.last_z
    }

    /// Long-run exponentially-weighted mean rate.
    pub fn mean(&self) -> f64 {
        self.mean
    }
}

/// One estimator's running one-bucket-ahead forecast-error account.
#[derive(Debug, Clone, Copy, Default)]
struct ErrAcc {
    /// Forecast made for the bucket currently open.
    pending: f64,
    have_pending: bool,
    abs_err_sum: f64,
    n: u64,
}

impl ErrAcc {
    fn settle(&mut self, actual: f64) {
        if self.have_pending {
            self.abs_err_sum += (self.pending - actual).abs();
            self.n += 1;
        }
    }

    fn predict(&mut self, forecast: f64) {
        self.pending = forecast;
        self.have_pending = true;
    }

    fn mae(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.abs_err_sum / self.n as f64
        }
    }
}

/// The composed arrival-rate forecaster the simulation kernel feeds from
/// `Routed` events. See the module docs for the determinism and
/// zero-allocation contracts.
#[derive(Debug, Clone)]
pub struct TrafficForecaster {
    bucket_s: f64,
    /// Start time of the currently open bucket.
    bucket_start: f64,
    /// Arrivals observed in the open bucket.
    open_count: u64,
    /// Latency-sensitive arrivals in the open bucket (fed only under a
    /// class-aware policy via [`TrafficForecaster::observe_class`];
    /// stays 0 — and costs nothing — in classless runs).
    premium_open: u64,
    /// Smoothed latency-sensitive share of the arrival rate, updated per
    /// non-empty closed bucket. Pure f64, allocation-free — the same
    /// determinism contract as the rate estimators.
    premium_share: Ewma,
    /// Rate of the most recently closed bucket (the burst-mode floor).
    last_rate: f64,
    /// Closed buckets folded so far.
    buckets_closed: u64,
    /// EWMA rate estimator.
    pub ewma: Ewma,
    /// Holt level+trend estimator.
    pub holt: Holt,
    /// Holt-Winters seasonal estimator.
    pub hw: HoltWinters,
    /// z-score burst detector.
    pub burst: BurstDetector,
    err_ewma: ErrAcc,
    err_holt: ErrAcc,
    err_hw: ErrAcc,
    /// Oracle mode: per-bucket true rates of the trace being served.
    oracle: Option<Vec<f64>>,
}

impl TrafficForecaster {
    /// Compose a forecaster over `bucket_s`-second rate buckets.
    pub fn new(
        bucket_s: f64,
        ewma: Ewma,
        holt: Holt,
        hw: HoltWinters,
        burst: BurstDetector,
    ) -> TrafficForecaster {
        assert!(bucket_s > 0.0, "bucket width must be positive");
        TrafficForecaster {
            bucket_s,
            bucket_start: 0.0,
            open_count: 0,
            premium_open: 0,
            premium_share: Ewma::new(0.3),
            last_rate: 0.0,
            buckets_closed: 0,
            ewma,
            holt,
            hw,
            burst,
            err_ewma: ErrAcc::default(),
            err_holt: ErrAcc::default(),
            err_hw: ErrAcc::default(),
            oracle: None,
        }
    }

    /// Switch to oracle mode: forecasts read the trace's true per-bucket
    /// rates instead of the estimators (which keep running, so the MAE
    /// report stays meaningful). `rates[i]` is the true arrival rate over
    /// `[i·bucket_s, (i+1)·bucket_s)`.
    pub fn set_oracle(&mut self, rates: Vec<f64>) {
        self.oracle = Some(rates);
    }

    /// Is this forecaster reading a trace oracle instead of estimating?
    pub fn is_oracle(&self) -> bool {
        self.oracle.is_some()
    }

    /// Bucket width in seconds.
    pub fn bucket_s(&self) -> f64 {
        self.bucket_s
    }

    /// Closed buckets folded so far.
    pub fn buckets_closed(&self) -> u64 {
        self.buckets_closed
    }

    /// Record one arrival at time `t` (seconds). Closes any buckets that
    /// ended at or before `t` first, so quiet gaps decay the estimators.
    pub fn observe(&mut self, t: f64) {
        self.advance(t);
        self.open_count += 1;
    }

    /// Tag the arrival just passed to [`TrafficForecaster::observe`] with
    /// its SLO class (call immediately after, same timestamp — `observe`
    /// already advanced the buckets). Classless kernels never call this,
    /// so the premium counters stay zero and the total-rate math — which
    /// this method does not touch — is bit-identical with or without it.
    pub fn observe_class(&mut self, class: crate::workload::SloClass) {
        if class == crate::workload::SloClass::LatencySensitive {
            self.premium_open += 1;
        }
    }

    /// Close every bucket that ended at or before `t` (zero-rate buckets
    /// for gaps with no arrivals). Called by the kernel's `ForecastTick`
    /// so lulls decay the estimators even with no traffic at all.
    pub fn advance(&mut self, t: f64) {
        while t >= self.bucket_start + self.bucket_s {
            let rate = self.open_count as f64 / self.bucket_s;
            // Per-class split: fold the closed bucket's premium share
            // before the counters reset. Empty buckets carry no share
            // information — the smoothed share holds through lulls
            // rather than decaying toward an arbitrary class.
            if self.open_count > 0 {
                let share =
                    (self.premium_open as f64 / self.open_count as f64).clamp(0.0, 1.0);
                self.premium_share.update(share);
            }
            self.close_bucket(rate);
            self.open_count = 0;
            self.premium_open = 0;
            self.bucket_start += self.bucket_s;
        }
    }

    fn close_bucket(&mut self, rate: f64) {
        // settle last tick's one-bucket-ahead forecasts against the truth
        self.err_ewma.settle(rate);
        self.err_holt.settle(rate);
        self.err_hw.settle(rate);
        // fold the bucket in
        self.ewma.update(rate);
        self.holt.update(rate);
        self.hw.update(rate);
        self.burst.update(rate);
        self.last_rate = rate;
        self.buckets_closed += 1;
        // stage next tick's one-bucket-ahead forecasts
        self.err_ewma.predict(self.ewma.value());
        self.err_holt.predict(self.holt.forecast(1.0));
        self.err_hw.predict(self.hw.forecast(1));
    }

    /// Forecast the arrival rate `h_s` seconds past the last
    /// `observe`/`advance` time. Estimator mode takes the *max* of the
    /// three smoothers (capacity planning wants the conservative
    /// envelope), floored at the latest closed bucket's rate while the
    /// burst detector is firing; oracle mode reads the trace's true rate.
    /// Clamped to ≥ 0 (a Holt downtrend extrapolates below zero).
    pub fn forecast(&self, h_s: f64) -> f64 {
        if let Some(rates) = &self.oracle {
            if rates.is_empty() {
                return 0.0;
            }
            let idx = ((self.bucket_start + h_s.max(0.0)) / self.bucket_s) as usize;
            return rates[idx.min(rates.len() - 1)];
        }
        let k = (h_s / self.bucket_s).ceil().max(1.0);
        let mut f =
            self.ewma.value().max(self.holt.forecast(k)).max(self.hw.forecast(k as usize));
        if self.burst.is_burst() {
            f = f.max(self.last_rate);
        }
        f.max(0.0)
    }

    /// Forecast the latency-sensitive arrival rate `h_s` seconds out: the
    /// total-rate forecast scaled by the smoothed premium share. Exactly
    /// 0.0 when no arrival was ever tagged premium.
    pub fn forecast_premium(&self, h_s: f64) -> f64 {
        self.forecast(h_s) * self.premium_share.value()
    }

    /// Smoothed latency-sensitive share of the arrival rate ∈ [0, 1].
    pub fn premium_share(&self) -> f64 {
        self.premium_share.value()
    }

    /// Mean absolute one-bucket-ahead error of (EWMA, Holt, Holt-Winters).
    pub fn mae(&self) -> (f64, f64, f64) {
        (self.err_ewma.mae(), self.err_holt.mae(), self.err_hw.mae())
    }

    /// Rate of the most recently closed bucket.
    pub fn last_rate(&self) -> f64 {
        self.last_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn forecaster(bucket_s: f64, period: usize) -> TrafficForecaster {
        TrafficForecaster::new(
            bucket_s,
            Ewma::new(0.3),
            Holt::new(0.4, 0.2),
            HoltWinters::new(0.4, 0.2, 0.3, period),
            BurstDetector::new(0.05, 3.0),
        )
    }

    /// Feed a rate function as evenly-spaced arrivals over [0, dur).
    fn feed(f: &mut TrafficForecaster, rate_at: impl Fn(f64) -> f64, dur: f64) {
        let mut t = 0.0;
        while t < dur {
            let r = rate_at(t).max(0.0);
            if r > 0.0 {
                let step = 1.0 / r;
                f.observe(t);
                t += step;
            } else {
                t += 0.25;
            }
        }
        f.advance(dur);
    }

    #[test]
    fn ewma_converges_to_constant_rate() {
        let mut f = forecaster(1.0, 8);
        feed(&mut f, |_| 20.0, 60.0);
        assert!((f.ewma.value() - 20.0).abs() < 2.0, "ewma {}", f.ewma.value());
        assert!((f.forecast(5.0) - 20.0).abs() < 4.0, "forecast {}", f.forecast(5.0));
        assert!(!f.burst.is_burst(), "steady traffic must not flag a burst");
    }

    #[test]
    fn holt_extrapolates_a_ramp() {
        let mut f = forecaster(1.0, 8);
        // 2 rps → 42 rps over 40 s: slope 1 rps/s
        feed(&mut f, |t| 2.0 + t, 40.0);
        let trend = f.holt.trend();
        assert!((0.5..1.5).contains(&trend), "trend {trend}");
        // forecast 10 s out must exceed the current level by ≈ the slope
        let now = f.holt.level();
        let ahead = f.holt.forecast(10.0);
        assert!(ahead > now + 4.0, "holt ahead {ahead} vs level {now}");
        // composed forecast is the conservative envelope, so ≥ holt's
        assert!(f.forecast(10.0) >= ahead - 1e-9);
    }

    #[test]
    fn holt_winters_horizons_zero_and_one_read_distinct_seasonal_slots() {
        // regression: `k.saturating_sub(1)` aliased horizons 0 and 1 onto
        // the same seasonal slot. Alternate low/high rates (period 2) and
        // pin each horizon to its own phase.
        let mut hw = HoltWinters::new(0.5, 0.1, 0.5, 2);
        for _ in 0..20 {
            hw.update(2.0); // phase 0
            hw.update(10.0); // phase 1
        }
        // last folded bucket: rate 10 at phase 1 → horizon 0 re-reads the
        // high phase, horizon 1 (one bucket ahead) lands on the low phase
        let now = hw.forecast(0);
        let next = hw.forecast(1);
        assert!(
            now - next > 3.0,
            "horizon 0 ({now}) must sit well above horizon 1 ({next})"
        );
        assert!((now - 10.0).abs() < (now - 2.0).abs(), "h=0 tracks the high phase");
        assert!((next - 2.0).abs() < (next - 10.0).abs(), "h=1 tracks the low phase");
        // two buckets ahead wraps back onto the high phase
        let wrap = hw.forecast(2);
        assert!((wrap - now).abs() < 2.0, "h=2 ({wrap}) wraps to h=0's phase ({now})");
    }

    #[test]
    fn holt_winters_learns_a_season() {
        let period_s = 20.0;
        let mut f = forecaster(1.0, period_s as usize);
        // three full sinusoidal cycles
        let rate = |t: f64| 20.0 * (1.0 + 0.7 * (std::f64::consts::TAU * t / period_s).sin());
        feed(&mut f, rate, 3.0 * period_s);
        // forecasting a quarter period ahead from the cycle start should
        // beat a seasonal-blind Holt at the crest
        let crest = f.hw.forecast(5); // t = 60 + 5 → crest phase
        let holt = f.holt.forecast(5.0);
        let truth = rate(65.0);
        assert!(
            (crest - truth).abs() < (holt - truth).abs() + 3.0,
            "hw {crest} vs holt {holt} vs truth {truth}"
        );
        assert!(f.buckets_closed() >= 58);
    }

    #[test]
    fn burst_detector_fires_on_step_and_not_on_steady() {
        let mut f = forecaster(1.0, 8);
        feed(&mut f, |_| 10.0, 30.0);
        assert!(!f.burst.is_burst());
        let base_forecast = f.forecast(1.0);
        // 3× step
        let mut t = 30.0;
        while t < 33.0 {
            f.observe(t);
            t += 1.0 / 30.0;
        }
        f.advance(33.0);
        assert!(f.burst.is_burst(), "z = {}", f.burst.last_z());
        // burst floors the forecast at the observed burst rate
        assert!(
            f.forecast(1.0) > base_forecast * 1.8,
            "burst forecast {} vs base {base_forecast}",
            f.forecast(1.0)
        );
    }

    #[test]
    fn quiet_gaps_decay_the_estimate() {
        let mut f = forecaster(1.0, 8);
        feed(&mut f, |_| 20.0, 20.0);
        let busy = f.forecast(1.0);
        f.advance(60.0); // 40 s of silence
        let idle = f.forecast(1.0);
        assert!(idle < busy * 0.25, "idle {idle} vs busy {busy}");
    }

    #[test]
    fn mae_tracks_prediction_quality() {
        let mut f = forecaster(1.0, 8);
        feed(&mut f, |_| 15.0, 40.0);
        let (e, h, hw) = f.mae();
        // constant traffic: every estimator converges, MAE stays small
        // relative to the rate (Poisson-free deterministic feed)
        assert!(e < 5.0, "ewma mae {e}");
        assert!(h < 5.0, "holt mae {h}");
        assert!(hw < 5.0, "hw mae {hw}");
        assert!(e >= 0.0 && h >= 0.0 && hw >= 0.0);
    }

    #[test]
    fn oracle_reads_true_future_rates() {
        let mut f = forecaster(1.0, 8);
        f.set_oracle(vec![5.0, 10.0, 40.0, 40.0]);
        assert!(f.is_oracle());
        f.advance(0.0);
        assert_eq!(f.forecast(0.0), 5.0);
        assert_eq!(f.forecast(2.5), 40.0);
        assert_eq!(f.forecast(99.0), 40.0, "clamps to the last bucket");
        f.observe(1.2); // open bucket 1
        assert_eq!(f.forecast(0.0), 10.0);
    }

    #[test]
    fn forecasts_are_deterministic() {
        let run = || {
            let mut f = forecaster(0.5, 16);
            feed(&mut f, |t| 8.0 + 0.4 * t, 30.0);
            (
                f.forecast(4.0).to_bits(),
                f.mae().0.to_bits(),
                f.mae().1.to_bits(),
                f.mae().2.to_bits(),
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn premium_share_splits_rate_without_touching_total() {
        use crate::workload::SloClass;
        let run = |tag: bool| {
            let mut f = forecaster(1.0, 8);
            let mut t = 0.0;
            let mut i = 0u64;
            while t < 30.0 {
                f.observe(t);
                if tag {
                    // 1 in 4 arrivals latency-sensitive
                    f.observe_class(if i % 4 == 0 {
                        SloClass::LatencySensitive
                    } else {
                        SloClass::BestEffort
                    });
                }
                i += 1;
                t += 0.1; // 10 rps
            }
            f.advance(30.0);
            f
        };
        let tagged = run(true);
        let untagged = run(false);
        // the per-class split never perturbs the total-rate math
        assert_eq!(tagged.forecast(2.0).to_bits(), untagged.forecast(2.0).to_bits());
        assert!(
            (tagged.premium_share() - 0.25).abs() < 0.05,
            "share {}",
            tagged.premium_share()
        );
        let total = tagged.forecast(2.0);
        let prem = tagged.forecast_premium(2.0);
        assert!((prem - total * tagged.premium_share()).abs() < 1e-12);
        // classless runs never tag arrivals: premium forecast is exactly 0
        assert_eq!(untagged.forecast_premium(2.0), 0.0);
        assert_eq!(untagged.premium_share(), 0.0);
    }

    #[test]
    fn forecast_never_negative_on_downtrend() {
        let mut f = forecaster(1.0, 8);
        feed(&mut f, |t| (40.0 - 2.0 * t).max(0.0), 25.0);
        assert!(f.holt.trend() < 0.0, "downtrend learned");
        assert!(f.forecast(30.0) >= 0.0);
    }
}
