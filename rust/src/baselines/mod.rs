//! Baseline serving policies over the same substrate (§6 comparisons).
//!
//! The paper compares CoCoServe against Hugging Face Transformers 4.51 and
//! vLLM 0.8.5. Rather than mock external systems, we express the behaviours
//! the paper attributes to each as [`SimPolicy`] configurations over the
//! identical simulator substrate — so every measured delta comes from the
//! *policy*, exactly the comparison the paper makes:
//!
//! | behaviour            | HFT-like          | vLLM-like        | CoCoServe      |
//! |----------------------|-------------------|------------------|----------------|
//! | batching             | static batch      | continuous       | continuous     |
//! | KV allocation        | contiguous max-len| paged            | paged          |
//! | OOM response         | fail + reload     | preempt          | scale-down     |
//! | scaling              | none              | none             | module-level   |

use crate::scheduler::SchedulerConfig;
use crate::sim::{OomBehavior, SimPolicy};

/// Hugging Face Transformers-like policy (§2.3's static baseline).
pub fn hft(batch: usize) -> SimPolicy {
    SimPolicy {
        scheduler: SchedulerConfig::hft(batch),
        paged_kv: false,
        autoscale: false,
        oom: OomBehavior::FailBatch,
    }
}

/// vLLM-like policy: continuous batching + paged KV, instance-level only.
pub fn vllm_like(max_batch: usize) -> SimPolicy {
    SimPolicy {
        scheduler: SchedulerConfig::continuous(max_batch),
        paged_kv: true,
        autoscale: false,
        oom: OomBehavior::Preempt,
    }
}

/// CoCoServe: continuous batching + paged KV + the §4 auto-scaler.
pub fn cocoserve(max_batch: usize) -> SimPolicy {
    SimPolicy {
        scheduler: SchedulerConfig::continuous(max_batch),
        paged_kv: true,
        autoscale: true,
        oom: OomBehavior::ScaleDown,
    }
}

/// CoCoServe with the auto-scaler disabled (ablation: module scaling off).
pub fn cocoserve_no_autoscale(max_batch: usize) -> SimPolicy {
    SimPolicy {
        scheduler: SchedulerConfig::continuous(max_batch),
        paged_kv: true,
        autoscale: false,
        oom: OomBehavior::ScaleDown,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::BatchPolicy;

    #[test]
    fn policies_differ_in_the_documented_axes() {
        let h = hft(15);
        let v = vllm_like(15);
        let c = cocoserve(15);
        assert!(matches!(h.scheduler.policy, BatchPolicy::Static { .. }));
        assert!(matches!(v.scheduler.policy, BatchPolicy::Continuous));
        assert!(!h.paged_kv && v.paged_kv && c.paged_kv);
        assert!(!h.autoscale && !v.autoscale && c.autoscale);
        assert_eq!(h.oom, OomBehavior::FailBatch);
        assert_eq!(v.oom, OomBehavior::Preempt);
        assert_eq!(c.oom, OomBehavior::ScaleDown);
    }
}
