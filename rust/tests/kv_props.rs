//! Property tests for the KV-cache allocators (`cocoserve::kvcache`).
//!
//! The in-module unit tests pin individual behaviours; these tapes drive
//! the allocators through randomized op sequences — including the elastic
//! `resize` the memory-pressure governor leans on — and check the
//! invariants the governor's correctness rests on:
//!
//! * block accounting is conserved under arbitrary
//!   add/append/remove/resize interleavings (and a failed resize changes
//!   nothing);
//! * paged waste is bounded by one partial block per live sequence — the
//!   Fig. 9 fragmentation bound;
//! * shrinking a pool to its live reservation and growing it back is
//!   bit-identical in both `KvStats` and pool capacity — so a governor
//!   episode that ends up a no-op cannot perturb a golden replay.
//!
//! Deterministic by default; set `PROP_SEED` to explore, `PROP_CASE` to
//! replay one case (see `cocoserve::util::prop`).

use cocoserve::kvcache::{ContiguousKvCache, KvCache, PagedKvCache};
use cocoserve::util::prop;
use cocoserve::util::rng::Rng;

/// Bytes per token — arbitrary but fixed; properties must not depend on it.
const BPT: f64 = 256.0;
const BLOCK_TOKENS: usize = 16;
const POOL: f64 = 64.0 * 16.0 * BPT; // 64 blocks

/// One randomized allocator op: (kind, sequence id, tokens-or-resize-%).
type Tape = Vec<(u8, u64, usize)>;

fn tape(r: &mut Rng, ops: usize) -> Tape {
    (0..ops)
        .map(|_| (r.below(4) as u8, r.below(8), 1 + r.below(200) as usize))
        .collect()
}

#[test]
fn prop_paged_conservation_under_resize_tapes() {
    prop::check(
        "paged-conservation-resize",
        |r: &mut Rng| tape(r, 80),
        |ops| {
            let mut c = PagedKvCache::new(POOL, BPT, BLOCK_TOKENS);
            let mut live: std::collections::BTreeSet<u64> = Default::default();
            for &(op, seq, n) in ops {
                let used_before = c.capacity_blocks() - c.free_blocks();
                match op {
                    0 if !live.contains(&seq) => {
                        if c.add_sequence(seq, n).is_ok() {
                            live.insert(seq);
                        }
                    }
                    1 if live.contains(&seq) => {
                        let _ = c.append_token(seq);
                    }
                    2 => {
                        c.remove_sequence(seq);
                        live.remove(&seq);
                    }
                    3 => {
                        // resize to 0–200% of the original pool: shrink may
                        // only reclaim free capacity, grow is unbounded here
                        let target = POOL * (n as f64 / 100.0);
                        let before = (used_before, c.capacity_blocks());
                        if c.resize(target).is_err() {
                            // a refused shrink must change nothing
                            let after =
                                (c.capacity_blocks() - c.free_blocks(), c.capacity_blocks());
                            if after != before {
                                return Err(format!(
                                    "failed resize mutated state: {before:?} -> {after:?}"
                                ));
                            }
                        }
                    }
                    _ => {}
                }
                // conservation: used blocks == reserved bytes, always
                let used = c.capacity_blocks() - c.free_blocks();
                let s = c.stats();
                let expect = (s.reserved_bytes / c.block_bytes()).round() as usize;
                if used != expect {
                    return Err(format!("blocks {used} != reserved {expect}"));
                }
                if s.live_bytes > s.reserved_bytes + 1e-9 {
                    return Err("live exceeds reserved".into());
                }
                if s.sequences != live.len() {
                    return Err(format!("{} tracked != {} live", s.sequences, live.len()));
                }
            }
            // draining everything returns the pool to fully free
            for s in live.iter() {
                c.remove_sequence(*s);
            }
            if c.free_blocks() != c.capacity_blocks() {
                return Err("drained pool is not fully free".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_paged_waste_bounded_by_one_partial_block_per_sequence() {
    prop::check(
        "paged-waste-bound",
        |r: &mut Rng| tape(r, 60),
        |ops| {
            let mut c = PagedKvCache::new(POOL, BPT, BLOCK_TOKENS);
            let mut live: std::collections::BTreeSet<u64> = Default::default();
            for &(op, seq, n) in ops {
                match op {
                    0 if !live.contains(&seq) => {
                        if c.add_sequence(seq, n).is_ok() {
                            live.insert(seq);
                        }
                    }
                    2 => {
                        c.remove_sequence(seq);
                        live.remove(&seq);
                    }
                    _ if live.contains(&seq) => {
                        let _ = c.append_token(seq);
                    }
                    _ => {}
                }
                let s = c.stats();
                // Fig. 9's paged bound: each sequence wastes strictly less
                // than one block (its final, possibly-partial block)
                let bound = s.sequences as f64 * c.block_bytes();
                if s.waste_bytes() >= bound + 1e-9 {
                    return Err(format!(
                        "waste {} >= {} ({} seqs × block)",
                        s.waste_bytes(),
                        bound,
                        s.sequences
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_resize_shrink_then_grow_is_bit_identical() {
    prop::check(
        "resize-roundtrip-bits",
        |r: &mut Rng| tape(r, 40),
        |ops| {
            let mut paged = PagedKvCache::new(POOL, BPT, BLOCK_TOKENS);
            let mut cont = ContiguousKvCache::new(POOL, BPT, 32);
            for &(op, seq, n) in ops {
                match op {
                    0 => {
                        let _ = paged.add_sequence(seq, n);
                        let _ = cont.add_sequence(seq, n.min(32));
                    }
                    1 => {
                        let _ = paged.append_token(seq);
                        let _ = cont.append_token(seq);
                    }
                    2 => {
                        paged.remove_sequence(seq);
                        cont.remove_sequence(seq);
                    }
                    _ => {}
                }
            }
            for (name, kv) in [
                ("paged", &mut paged as &mut dyn KvCache),
                ("contiguous", &mut cont as &mut dyn KvCache),
            ] {
                let pool0 = kv.pool_bytes();
                let s0 = kv.stats();
                // shrink to exactly the live reservation (always legal)…
                kv.resize(s0.reserved_bytes)
                    .map_err(|d| format!("{name}: shrink-to-reserved refused ({d})"))?;
                // …then grow back to the original capacity
                kv.resize(pool0)
                    .map_err(|d| format!("{name}: grow-back refused ({d})"))?;
                let s1 = kv.stats();
                let same = kv.pool_bytes().to_bits() == pool0.to_bits()
                    && s1.live_bytes.to_bits() == s0.live_bytes.to_bits()
                    && s1.reserved_bytes.to_bits() == s0.reserved_bytes.to_bits()
                    && s1.sequences == s0.sequences;
                if !same {
                    return Err(format!(
                        "{name}: round-trip drifted: {s0:?}/{pool0} -> {s1:?}/{}",
                        kv.pool_bytes()
                    ));
                }
            }
            Ok(())
        },
    );
}
